//! Persistent device-side KV window + dirty-range upload — DESIGN.md §6.
//!
//! PR 1 made the *host* gather memcpy O(changed); the host→device push
//! of the assembled window was still a whole-buffer upload every step.
//! [`DeviceWindow`] closes that gap: it models one persistent device
//! buffer per pool (K or V) and pushes only the coalesced byte ranges
//! the [`ResidentWindow`](crate::kvpage::ResidentWindow) reports as
//! changed since the previous upload ([`UploadPlan::Ranges`]), falling
//! back to a whole-buffer upload ([`UploadPlan::Full`]) on the first
//! step, any residency or buffer loss, a backend without range support,
//! or when delta transfer is disabled.
//!
//! Two backings:
//!
//! * [`DeviceWindow::sim`] — a real in-process device-buffer model
//!   (`xla::SimDeviceBuffer`) that performs per-range copies, so benches
//!   and property tests assert uploaded bytes/step and device-side
//!   contents without PJRT hardware. On range-capable hardware this is
//!   the shape of the real path.
//! * [`DeviceWindow::pjrt`] — accounting for the real xla_extension
//!   0.5.1 path, which cannot update a device buffer in place: every
//!   `upload_ranges` refuses, `apply` falls back to a full upload, and
//!   the actual `buffer_from_host` transfer keeps happening at execute
//!   time (`runtime::Runtime::run`). The counters still record what the
//!   step *would* move on range-capable hardware vs what it does move.
//!
//! The contract for [`DeviceWindow::upload_ranges`]: the caller
//! guarantees the ranges cover every element that changed in `host`
//! since the previous successful upload, at the same buffer length.
//! `ResidentWindow::plan_for` (against this buffer's
//! [`DeviceWindow::epoch`]) provides exactly that; equivalence with
//! the full-upload path is property-tested in
//! `rust/tests/proptest_kvpage.rs`.

use crate::kvpage::window::UploadPlan;
use crate::util::profile::{self, Phase};
use crate::util::Result;
use crate::{bail, ensure};

/// Cumulative host→device upload counters for one device window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UploadStats {
    /// Whole-buffer uploads (fallback path).
    pub full_uploads: u64,
    /// Delta uploads (only dirty ranges pushed).
    pub delta_uploads: u64,
    /// Individual contiguous ranges pushed across all delta uploads.
    pub ranges_pushed: u64,
    /// Bytes moved host→device (full + delta).
    pub bytes_uploaded: u64,
    /// Bytes moved by the most recent upload only.
    pub last_bytes: u64,
}

impl UploadStats {
    /// Element-wise sum (engines hold one window per pool).
    pub fn plus(&self, o: &UploadStats) -> UploadStats {
        UploadStats {
            full_uploads: self.full_uploads + o.full_uploads,
            delta_uploads: self.delta_uploads + o.delta_uploads,
            ranges_pushed: self.ranges_pushed + o.ranges_pushed,
            bytes_uploaded: self.bytes_uploaded + o.bytes_uploaded,
            last_bytes: self.last_bytes + o.last_bytes,
        }
    }
}

enum Backing {
    /// Modeled persistent buffer with per-range copies (offline and
    /// range-capable hardware shape).
    Sim(xla::SimDeviceBuffer),
    /// Real PJRT 0.5.1: no in-place update — accounting only, the
    /// transfer itself happens at execute time.
    Pjrt,
}

/// One persistent device-resident window buffer (K or V pool view).
pub struct DeviceWindow {
    backing: Backing,
    /// Elements resident on device (0 until the first full upload).
    len: usize,
    /// False after `invalidate` (buffer loss): the next `apply` must be
    /// a full upload.
    valid: bool,
    /// Window epoch the resident contents are current through
    /// (`ResidentWindow::plan_for` handoff; 0 = never uploaded/lost).
    epoch: u64,
    stats: UploadStats,
}

impl DeviceWindow {
    /// Modeled-buffer backing (benches, tests, offline runs).
    pub fn sim() -> Self {
        Self::with_backing(Backing::Sim(xla::SimDeviceBuffer::new()))
    }

    /// Accounting-only backing for the real PJRT path.
    pub fn pjrt() -> Self {
        Self::with_backing(Backing::Pjrt)
    }

    fn with_backing(backing: Backing) -> Self {
        DeviceWindow {
            backing,
            len: 0,
            valid: false,
            epoch: 0,
            stats: UploadStats::default(),
        }
    }

    /// Window epoch the buffer is current through (0 = none).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Whether the backing can push individual ranges.
    pub fn supports_ranges(&self) -> bool {
        matches!(self.backing, Backing::Sim(_))
    }

    /// Modeled ns this buffer has spent receiving transfers (sim
    /// backing; 0 on the accounting-only PJRT path).
    pub fn busy_ns(&self) -> u64 {
        match &self.backing {
            Backing::Sim(buf) => buf.busy_ns(),
            Backing::Pjrt => 0,
        }
    }

    /// Wall-clock busy simulation: every copy sleeps its modeled ns ×
    /// `scale` (sim backing only; see `xla::SimDeviceBuffer`). The
    /// measured-overlap bench turns this on so hidden transfer time is
    /// *observed*, not derived.
    pub fn set_sleep_scale(&mut self, scale: f64) {
        if let Backing::Sim(buf) = &mut self.backing {
            buf.set_sleep_scale(scale);
        }
    }

    /// Drop the device buffer (failed execute, device reset). The next
    /// `apply` falls back to a full upload whatever the plan says.
    pub fn invalidate(&mut self) {
        self.valid = false;
        self.epoch = 0;
    }

    /// A delta upload against the resident buffer would be sound.
    pub fn can_delta(&self, host_len: usize) -> bool {
        self.valid && self.len == host_len && self.supports_ranges()
    }

    /// Whole-buffer upload; (re)sizes the device buffer.
    pub fn upload_full(&mut self, host: &[f32]) {
        let _p = profile::span(Phase::UploadFull);
        if let Backing::Sim(buf) = &mut self.backing {
            buf.write_full(host);
        }
        self.len = host.len();
        self.valid = true;
        let bytes = 4 * host.len() as u64;
        self.stats.full_uploads += 1;
        self.stats.bytes_uploaded += bytes;
        self.stats.last_bytes = bytes;
    }

    /// Push only `ranges` (element offset, element count), which must
    /// cover everything that changed in `host` since the previous
    /// successful upload. Errors — so callers can fall back to
    /// `upload_full` — when the backing has no range support or the
    /// resident buffer is missing, stale, or a different size.
    pub fn upload_ranges(&mut self, host: &[f32],
                         ranges: &[(usize, usize)]) -> Result<()> {
        ensure!(self.can_delta(host.len()),
                "device window cannot take a delta upload (valid={}, \
                 resident {} vs host {} elements, range support {})",
                self.valid, self.len, host.len(),
                self.supports_ranges());
        let _p = profile::span(Phase::UploadDelta);
        let Backing::Sim(buf) = &mut self.backing else {
            bail!("unreachable: range upload without range support");
        };
        let mut bytes = 0u64;
        for &(off, n) in ranges {
            ensure!(off + n <= host.len(),
                    "upload range [{off}, {}) exceeds host window of {} \
                     elements", off + n, host.len());
            buf.write_range(off, &host[off..off + n])?;
            bytes += 4 * n as u64;
        }
        self.note_delta_upload(ranges.len() as u64, bytes);
        Ok(())
    }

    /// Shared stats bookkeeping for the two range-push paths (live
    /// host slices vs snapshot-captured data) — keep them in sync.
    fn note_delta_upload(&mut self, n_ranges: u64, bytes: u64) {
        self.stats.delta_uploads += 1;
        self.stats.ranges_pushed += n_ranges;
        self.stats.bytes_uploaded += bytes;
        self.stats.last_bytes = bytes;
    }

    /// Execute an [`UploadPlan`] from the resident window, falling back
    /// to a full upload whenever a delta is not possible (plan says
    /// full, backing lacks range support, buffer lost or resized).
    pub fn apply(&mut self, host: &[f32], plan: &UploadPlan) {
        match plan {
            UploadPlan::Ranges(ranges)
                if self.can_delta(host.len()) =>
            {
                // can_delta pre-checked: only a malformed range can
                // fail, and that is a protocol bug upstream
                self.upload_ranges(host, ranges)
                    .expect("checked delta upload failed");
            }
            _ => self.upload_full(host),
        }
    }

    /// [`DeviceWindow::apply`] plus the epoch handoff: the buffer
    /// becomes current through `through` (the epoch
    /// `ResidentWindow::plan_for` returned alongside the plan).
    pub fn apply_at(&mut self, host: &[f32], plan: &UploadPlan,
                    through: u64) {
        self.apply(host, plan);
        self.epoch = through;
    }

    /// [`DeviceWindow::upload_ranges`] plus the epoch handoff. On error
    /// the epoch is untouched, so a later plan re-covers the ranges.
    pub fn upload_ranges_at(&mut self, host: &[f32],
                            ranges: &[(usize, usize)], through: u64)
                            -> Result<()> {
        self.upload_ranges(host, ranges)?;
        self.epoch = through;
        Ok(())
    }

    /// Push ranges whose bytes were captured at snapshot time
    /// (`ResidentWindow::snapshot_for`): `data` holds the ranges'
    /// elements concatenated in order. This is the staged (pipelined)
    /// upload — it must not read the live host buffer, which the
    /// scatter may be rewriting while the transfer is in flight.
    pub fn upload_captured(&mut self, host_len: usize,
                           ranges: &[(usize, usize)], data: &[f32],
                           through: u64) -> Result<()> {
        ensure!(self.can_delta(host_len),
                "device window cannot take a captured delta (valid={}, \
                 resident {} vs host {} elements, range support {})",
                self.valid, self.len, host_len, self.supports_ranges());
        let _p = profile::span(Phase::UploadDelta);
        let Backing::Sim(buf) = &mut self.backing else {
            bail!("unreachable: range upload without range support");
        };
        let mut cursor = 0usize;
        let mut bytes = 0u64;
        for &(off, n) in ranges {
            ensure!(cursor + n <= data.len(),
                    "captured upload underrun: range [{off}, {}) wants \
                     {n} elements, {} captured",
                    off + n, data.len() - cursor);
            ensure!(off + n <= host_len,
                    "upload range [{off}, {}) exceeds host window of {} \
                     elements", off + n, host_len);
            buf.write_range(off, &data[cursor..cursor + n])?;
            cursor += n;
            bytes += 4 * n as u64;
        }
        self.note_delta_upload(ranges.len() as u64, bytes);
        self.epoch = through;
        Ok(())
    }

    /// Whole-buffer upload from bytes captured at snapshot time (the
    /// staged full path: double-buffer refill, `window_upload = full`).
    pub fn upload_full_captured(&mut self, data: &[f32], through: u64) {
        self.upload_full(data);
        self.epoch = through;
    }

    /// Seeded silent corruption for fault injection: bend one resident
    /// element's mantissa in place (sim backing only — the accounting
    /// PJRT path has no modeled bytes to damage). Touches neither the
    /// epoch nor the upload counters, so nothing downstream can tell
    /// the buffer is wrong without re-reading it — exactly the failure
    /// the execute-boundary device audit exists to catch (DESIGN.md
    /// §14). Returns whether an element was actually damaged.
    pub fn corrupt_for_test(&mut self, salt: u64) -> bool {
        if !self.valid || self.len == 0 {
            return false;
        }
        let Backing::Sim(buf) = &mut self.backing else {
            return false;
        };
        let idx = (salt as usize) % self.len;
        let cur = buf.as_slice()[idx];
        // Mantissa-only flip: never manufactures NaN/Inf from a
        // finite value, so the damage survives arithmetic and
        // comparisons instead of tripping debug asserts.
        let bent = f32::from_bits(cur.to_bits() ^ 0x0040_0001);
        buf.write_range(idx, &[bent])
            .expect("in-bounds single-element corruption write");
        true
    }

    /// Device-side contents (sim backing only; tests and benches verify
    /// the dirty-range protocol against these).
    pub fn contents(&self) -> Option<&[f32]> {
        match &self.backing {
            Backing::Sim(buf) if self.valid => Some(buf.as_slice()),
            _ => None,
        }
    }

    /// Cumulative counters. Delta reporting lives one level up
    /// (`TransferPipeline::take_upload_unreported` snapshots these
    /// totals), so a single reporting scheme owns the baselines.
    pub fn stats(&self) -> &UploadStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_delta_uploads_only_move_range_bytes() {
        let mut dev = DeviceWindow::sim();
        let mut host = vec![0.0f32; 64];
        dev.apply(&host, &UploadPlan::Full);
        assert_eq!(dev.stats().last_bytes, 64 * 4);
        assert_eq!(dev.contents().unwrap(), &host[..]);

        host[8..12].fill(3.0);
        host[40..44].fill(7.0);
        dev.apply(&host, &UploadPlan::Ranges(vec![(8, 4), (40, 4)]));
        assert_eq!(dev.stats().last_bytes, 8 * 4);
        assert_eq!(dev.stats().delta_uploads, 1);
        assert_eq!(dev.stats().ranges_pushed, 2);
        assert_eq!(dev.contents().unwrap(), &host[..]);
    }

    #[test]
    fn invalidation_and_resize_force_full_upload() {
        let mut dev = DeviceWindow::sim();
        let host = vec![1.0f32; 16];
        dev.apply(&host, &UploadPlan::Ranges(vec![(0, 1)]));
        assert_eq!(dev.stats().full_uploads, 1, "first upload is full");

        dev.invalidate();
        assert!(!dev.can_delta(host.len()));
        assert!(dev.contents().is_none(), "lost buffer is unreadable");
        dev.apply(&host, &UploadPlan::Ranges(vec![(0, 1)]));
        assert_eq!(dev.stats().full_uploads, 2, "loss → full upload");

        let grown = vec![2.0f32; 32];
        dev.apply(&grown, &UploadPlan::Ranges(vec![(0, 1)]));
        assert_eq!(dev.stats().full_uploads, 3, "resize → full upload");
        assert_eq!(dev.contents().unwrap(), &grown[..]);
    }

    #[test]
    fn pjrt_backing_counts_but_never_deltas() {
        let mut dev = DeviceWindow::pjrt();
        let host = vec![0.5f32; 8];
        assert!(!dev.supports_ranges());
        dev.apply(&host, &UploadPlan::Full);
        dev.apply(&host, &UploadPlan::Ranges(vec![(0, 2)]));
        assert_eq!(dev.stats().full_uploads, 2,
                   "0.5.1 path falls back to full uploads");
        assert_eq!(dev.stats().delta_uploads, 0);
        assert!(dev.contents().is_none(), "no modeled contents");
        assert!(dev.upload_ranges(&host, &[(0, 1)]).is_err());
    }

    #[test]
    fn corruption_hook_bends_one_element_silently() {
        let mut dev = DeviceWindow::sim();
        let host = vec![1.0f32; 16];
        dev.apply_at(&host, &UploadPlan::Full, 7);
        let before = *dev.stats();

        assert!(dev.corrupt_for_test(5));
        let got = dev.contents().unwrap();
        let diffs: Vec<usize> = (0..host.len())
            .filter(|&i| got[i].to_bits() != host[i].to_bits())
            .collect();
        assert_eq!(diffs, vec![5], "exactly one element bent");
        assert!(got[5].is_finite(), "mantissa flip stays finite");
        assert_eq!(dev.epoch(), 7, "epoch untouched — damage is silent");
        assert_eq!(*dev.stats(), before, "no counters move");

        let mut lost = DeviceWindow::sim();
        assert!(!lost.corrupt_for_test(1), "no resident buffer");
        let mut acc = DeviceWindow::pjrt();
        acc.apply(&host, &UploadPlan::Full);
        assert!(!acc.corrupt_for_test(1), "no modeled bytes on pjrt");
    }

    #[test]
    fn stats_accumulate_and_sum() {
        let mut dev = DeviceWindow::sim();
        let host = vec![0.0f32; 4];
        dev.upload_full(&host);
        let d = *dev.stats();
        assert_eq!(d.full_uploads, 1);
        assert_eq!(d.bytes_uploaded, 16);
        let merged = d.plus(dev.stats());
        assert_eq!(merged.full_uploads, 2, "element-wise sum");
        assert_eq!(merged.bytes_uploaded, 32);
    }
}
