//! Asynchronous copy engine — a dedicated transfer worker thread per
//! pool set (DESIGN.md §9).
//!
//! PR 3's double-buffered pipeline *modeled* the overlap of step N+1's
//! KV-window upload with step N's execute: every byte still moved
//! synchronously on the engine thread. This module makes the overlap
//! real, the way vLLM-class servers run transfers on their own stream
//! (Kwon et al., arXiv 2309.06180):
//!
//! * [`CopyStream`] owns one transfer worker thread. [`CopyStream::
//!   submit`] moves an epoch-tagged [`CopyJob`] — the device pair being
//!   staged plus the bytes `ResidentWindow::snapshot_for` captured (by
//!   ownership, no copy) — onto a **bounded** queue and returns a
//!   [`Fence`]; a full queue blocks the submitter, which is the
//!   backpressure story (an engine that outruns the interconnect must
//!   stall *somewhere*; better at submit than unbounded memory).
//! * [`Fence::wait`] blocks until the worker finished the upload and
//!   hands the device pair back — the engine calls it at the next
//!   stage boundary (`engine::pipeline::TransferPipeline::begin_step`),
//!   so in steady state the wait is ~0: the transfer already completed
//!   under the previous execute.
//! * **Poison detection**: a dead worker (panic mid-transfer) surfaces
//!   as an error from `submit` (the job, and its device pair, are
//!   handed back) or from `Fence::wait` (the in-flight pair died with
//!   the thread). The pipeline treats either exactly like device-buffer
//!   loss: collapse to the inline serial path, full-sync the next
//!   front, keep serving.
//! * **Clean shutdown drains**: dropping the stream closes the queue
//!   and joins the worker, which finishes every queued job (and
//!   answers every outstanding fence) before exiting.
//!
//! [`DevicePair`] (the K+V device windows that move in lockstep under
//! one plan) lives here so the worker can own a pair while a transfer
//! is in flight; `engine::pipeline` re-exports it.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::kvpage::StagedUpload;
use crate::runtime::DeviceWindow;

/// K and V device windows moving in lockstep (one plan drives both).
pub struct DevicePair {
    pub k: DeviceWindow,
    pub v: DeviceWindow,
}

impl DevicePair {
    /// Modeled-buffer backing (benches, proptests, offline runs).
    pub fn sim() -> Self {
        DevicePair { k: DeviceWindow::sim(), v: DeviceWindow::sim() }
    }

    /// Accounting-only backing for the real PJRT 0.5.1 path.
    pub fn pjrt() -> Self {
        DevicePair { k: DeviceWindow::pjrt(), v: DeviceWindow::pjrt() }
    }

    /// Epoch the pair is current through (a lost half drags it to 0).
    pub fn epoch(&self) -> u64 {
        self.k.epoch().min(self.v.epoch())
    }

    pub fn supports_ranges(&self) -> bool {
        self.k.supports_ranges() && self.v.supports_ranges()
    }

    pub fn invalidate(&mut self) {
        self.k.invalidate();
        self.v.invalidate();
    }

    /// A delta upload against both resident buffers would be sound.
    pub fn can_delta(&self, host_len: usize) -> bool {
        self.k.can_delta(host_len) && self.v.can_delta(host_len)
    }

    /// Modeled ns both halves have spent receiving transfers.
    pub fn busy_ns(&self) -> u64 {
        self.k.busy_ns() + self.v.busy_ns()
    }
}

/// One staged upload handed to the transfer worker: the device pair
/// being staged plus the snapshot whose bytes it applies. The pair
/// travels *by ownership* — while the transfer is in flight nobody
/// else can touch (or observe a half-written) device buffer.
pub struct CopyJob {
    pub pair: DevicePair,
    pub snap: StagedUpload,
    /// Host window length the captured ranges index into.
    pub host_len: usize,
}

/// What comes back over a [`Fence`]: the device pair, whether the
/// captured ranges applied cleanly to both halves, the wall ns the
/// worker spent (including any simulated DMA busy time), and the
/// capture buffers for the window arena to recycle.
pub struct CopyDone {
    pub pair: DevicePair,
    /// False when a half refused the captured ranges (buffer lost
    /// between capture and apply) — the pair's epoch is stale and the
    /// caller must not rotate it in as staged.
    pub ok: bool,
    /// Wall-clock ns the worker spent applying this job.
    pub wall_ns: u64,
    pub k_data: Vec<f32>,
    pub v_data: Vec<f32>,
    pub ranges: Vec<(usize, usize)>,
}

/// The transfer worker died (panicked) with the job's device pair.
#[derive(Debug)]
pub struct Poisoned;

/// Completion ticket for one submitted [`CopyJob`].
pub struct Fence {
    rx: mpsc::Receiver<CopyDone>,
}

impl Fence {
    /// Block until the transfer finished (or the worker died). In
    /// steady pipelined decode the transfer completed under the
    /// previous execute and this returns immediately. Consumes the
    /// fence — the reply channel is one-shot, so there is no
    /// non-blocking probe to mix up with it.
    pub fn wait(self) -> Result<CopyDone, Poisoned> {
        self.rx.recv().map_err(|_| Poisoned)
    }
}

enum WorkItem {
    // boxed: a CopyJob carries a device pair + capture buffers, far
    // larger than the poison marker
    Upload { job: Box<CopyJob>, reply: mpsc::Sender<CopyDone> },
    /// Test hook: makes the worker panic mid-queue, simulating a crash
    /// in the transfer path (poisoned-worker recovery coverage).
    Poison,
}

/// Dedicated transfer worker thread + bounded submission queue.
pub struct CopyStream {
    tx: Option<mpsc::SyncSender<WorkItem>>,
    worker: Option<JoinHandle<()>>,
}

/// Submission-queue depth. The pipeline keeps at most one upload in
/// flight per pool set, so 2 gives one slot of slack; anything deeper
/// only hides backpressure.
const QUEUE_DEPTH: usize = 2;

impl CopyStream {
    pub fn spawn() -> Self {
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(QUEUE_DEPTH);
        let worker = std::thread::Builder::new()
            .name("pf-copy-stream".into())
            .spawn(move || worker_loop(rx))
            .expect("spawning copy-stream worker");
        CopyStream { tx: Some(tx), worker: Some(worker) }
    }

    /// Enqueue an upload; blocks when the queue is full (backpressure).
    /// A dead worker hands the job — and its device pair — straight
    /// back (boxed) so the caller can fall to the inline path without
    /// losing the buffer.
    pub fn submit(&self, job: CopyJob)
                  -> Result<Fence, Box<CopyJob>> {
        let (reply, rx) = mpsc::channel();
        match self
            .tx
            .as_ref()
            .expect("copy stream submitted after shutdown")
            .send(WorkItem::Upload { job: Box::new(job), reply })
        {
            Ok(()) => Ok(Fence { rx }),
            Err(mpsc::SendError(WorkItem::Upload { job, .. })) => {
                Err(job)
            }
            Err(mpsc::SendError(WorkItem::Poison)) => unreachable!(),
        }
    }

    /// Test hook: crash the worker after it drains what is already
    /// queued. Subsequent submits/fences report poison.
    pub fn inject_poison(&self) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(WorkItem::Poison);
        }
    }
}

impl Drop for CopyStream {
    fn drop(&mut self) {
        // closing the queue lets the worker drain remaining jobs and
        // exit; join so no transfer outlives the stream
        drop(self.tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join(); // a poisoned worker already unwound
        }
    }
}

fn worker_loop(rx: mpsc::Receiver<WorkItem>) {
    while let Ok(item) = rx.recv() {
        match item {
            WorkItem::Upload { job, reply } => {
                // a dropped fence (drain/shutdown race) is fine: the
                // transfer still completed, only nobody is listening
                let _ = reply.send(run_job(*job));
            }
            WorkItem::Poison => {
                panic!("copy stream poisoned (test hook)");
            }
        }
    }
}

/// Apply one staged upload to both halves of the pair. Mirrors the
/// inline `TransferPipeline` staging path exactly — same captured-data
/// entry points, same failure semantics — so serial and threaded runs
/// produce identical device states.
fn run_job(mut job: CopyJob) -> CopyDone {
    let t = Instant::now();
    let snap = job.snap;
    let ok = if snap.full {
        job.pair.k.upload_full_captured(&snap.k_data, snap.through);
        job.pair.v.upload_full_captured(&snap.v_data, snap.through);
        true
    } else {
        let k_ok = job
            .pair
            .k
            .upload_captured(job.host_len, &snap.ranges, &snap.k_data,
                             snap.through)
            .is_ok();
        let v_ok = job
            .pair
            .v
            .upload_captured(job.host_len, &snap.ranges, &snap.v_data,
                             snap.through)
            .is_ok();
        k_ok && v_ok
    };
    CopyDone {
        pair: job.pair,
        ok,
        wall_ns: t.elapsed().as_nanos() as u64,
        k_data: snap.k_data,
        v_data: snap.v_data,
        ranges: snap.ranges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_snap(data: Vec<f32>, through: u64) -> StagedUpload {
        StagedUpload {
            through,
            full: true,
            ranges: Vec::new(),
            v_data: data.clone(),
            k_data: data,
        }
    }

    #[test]
    fn submit_wait_roundtrip_applies_the_upload() {
        let stream = CopyStream::spawn();
        let mut pair = DevicePair::sim();
        pair.k.upload_full(&[0.0; 16]);
        pair.v.upload_full(&[0.0; 16]);

        let snap = StagedUpload {
            through: 7,
            full: false,
            ranges: vec![(4, 2)],
            k_data: vec![1.0, 2.0],
            v_data: vec![-1.0, -2.0],
        };
        let Ok(fence) = stream.submit(CopyJob { pair, snap, host_len: 16 })
        else {
            panic!("live worker must accept jobs");
        };
        let done = fence.wait().expect("worker answers");
        assert!(done.ok);
        assert_eq!(done.pair.epoch(), 7, "epoch handoff rode the job");
        assert_eq!(&done.pair.k.contents().unwrap()[4..6], &[1.0, 2.0]);
        assert_eq!(&done.pair.v.contents().unwrap()[4..6],
                   &[-1.0, -2.0]);
        assert_eq!(done.k_data, vec![1.0, 2.0],
                   "capture buffers come back for the arena");
    }

    #[test]
    fn stale_pair_reports_not_ok_but_survives() {
        let stream = CopyStream::spawn();
        let pair = DevicePair::sim(); // never uploaded: can_delta false
        let snap = StagedUpload {
            through: 3,
            full: false,
            ranges: vec![(0, 1)],
            k_data: vec![1.0],
            v_data: vec![1.0],
        };
        let Ok(fence) = stream.submit(CopyJob { pair, snap, host_len: 8 })
        else {
            panic!("live worker must accept jobs");
        };
        let done = fence.wait().unwrap();
        assert!(!done.ok, "captured ranges must refuse a lost buffer");
        assert_eq!(done.pair.epoch(), 0, "failed apply keeps the epoch");
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let stream = CopyStream::spawn();
        let mut fences = Vec::new();
        for i in 0..4u64 {
            let mut pair = DevicePair::sim();
            pair.k.upload_full(&[0.0; 8]);
            pair.v.upload_full(&[0.0; 8]);
            let Ok(fence) = stream.submit(CopyJob {
                pair,
                snap: full_snap(vec![i as f32; 8], i + 1),
                host_len: 8,
            }) else {
                panic!("submit while live must succeed");
            };
            fences.push((i, fence));
        }
        drop(stream); // closes the queue, joins the worker
        for (i, fence) in fences {
            let done = fence.wait().expect("queued job drained");
            assert!(done.ok);
            assert_eq!(done.pair.k.contents().unwrap()[0], i as f32,
                       "job {i} applied before shutdown");
        }
    }

    #[test]
    fn poisoned_worker_fails_fences_and_submits() {
        let stream = CopyStream::spawn();
        stream.inject_poison();
        // whether a job lands before or after the worker unwinds, the
        // poison must surface within a bounded number of attempts —
        // either as a refused submit (pair handed back) or a dead fence
        let mut pair = Some(DevicePair::sim());
        let mut poisoned = false;
        for round in 0..50 {
            let job = CopyJob {
                pair: pair.take().unwrap(),
                snap: full_snap(vec![0.5; 4], round + 1),
                host_len: 4,
            };
            match stream.submit(job) {
                Err(job) => {
                    pair = Some(job.pair); // pair recovered intact
                    poisoned = true;
                    break;
                }
                Ok(fence) => match fence.wait() {
                    Err(Poisoned) => {
                        poisoned = true;
                        break;
                    }
                    Ok(done) => pair = Some(done.pair),
                },
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(poisoned, "poison never surfaced");
        drop(stream); // join of a panicked worker must not hang
    }

    #[test]
    fn device_pair_epoch_is_min_of_halves() {
        let mut pair = DevicePair::sim();
        pair.k.upload_full(&[0.0; 4]);
        pair.v.upload_full(&[0.0; 4]);
        assert!(pair.supports_ranges());
        assert!(pair.can_delta(4));
        pair.v.invalidate();
        assert_eq!(pair.epoch(), 0, "lost half drags the pair to 0");
        assert!(!pair.can_delta(4));
    }
}
