//! Asynchronous copy engine — dedicated transfer workers per pool set,
//! or one shared multiplexed engine for every pool set in the process
//! (DESIGN.md §9–10).
//!
//! PR 3's double-buffered pipeline *modeled* the overlap of step N+1's
//! KV-window upload with step N's execute: every byte still moved
//! synchronously on the engine thread. This module makes the overlap
//! real, the way vLLM-class servers run transfers on their own stream
//! (Kwon et al., arXiv 2309.06180):
//!
//! * [`CopyStream`] is one pool set's submission handle.
//!   [`CopyStream::submit`] moves an epoch-tagged [`CopyJob`] — the
//!   device pair being staged plus the bytes
//!   `ResidentWindow::snapshot_for` captured (by ownership, no copy) —
//!   onto a **bounded** queue and returns a [`Fence`]; a full queue
//!   blocks the submitter, which is the backpressure story (an engine
//!   that outruns the interconnect must stall *somewhere*; better at
//!   submit than unbounded memory).
//! * [`CopyStream::spawn`] backs the handle with a dedicated worker
//!   thread (the PR 4 one-worker-per-pool-set topology, still the
//!   default). [`CopyEngine::stream`] instead registers a tagged
//!   **lane** on a shared multiplexed engine: a single worker (or
//!   small fixed pool) services every pool set's lane round-robin, so
//!   one pool's large upload cannot starve a sibling's, per-pool
//!   submission order is preserved, and multi-model serving shares one
//!   transfer thread instead of spawning one per model (DESIGN.md
//!   §10).
//! * [`Fence::wait`] blocks until the worker finished the upload and
//!   hands the device pair back — the engine calls it at the next
//!   stage boundary (`engine::pipeline::TransferPipeline::begin_step`),
//!   so in steady state the wait is ~0: the transfer already completed
//!   under the previous execute.
//! * **Poison detection**: a dead worker (panic mid-transfer) surfaces
//!   as an error from `submit` (the job, and its device pair, are
//!   handed back) or from `Fence::wait` (the in-flight pair died with
//!   the thread). The pipeline treats either exactly like device-buffer
//!   loss: collapse to the inline serial path, full-sync the next
//!   front, keep serving. On the shared engine the panic is **caught
//!   per lane**: a crash while servicing pool A poisons only A's lane
//!   (its queued fences fail, its submits are refused), while every
//!   sibling pool keeps its live worker — the isolation the
//!   cross-pool stress suite (`tests/copy_stream_multiplex.rs`) pins.
//! * **Clean shutdown drains**: dropping a dedicated stream (or the
//!   last [`CopyEngine`] handle) closes the queue(s) and joins the
//!   worker(s), which finish every queued job — and answer every
//!   outstanding fence — before exiting.
//!
//! [`DevicePair`] (the K+V device windows that move in lockstep under
//! one plan) lives here so a worker can own a pair while a transfer
//! is in flight; `engine::pipeline` re-exports it.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::kvpage::StagedUpload;
use crate::runtime::DeviceWindow;

/// K and V device windows moving in lockstep (one plan drives both).
pub struct DevicePair {
    pub k: DeviceWindow,
    pub v: DeviceWindow,
}

impl DevicePair {
    /// Modeled-buffer backing (benches, proptests, offline runs).
    pub fn sim() -> Self {
        DevicePair { k: DeviceWindow::sim(), v: DeviceWindow::sim() }
    }

    /// Accounting-only backing for the real PJRT 0.5.1 path.
    pub fn pjrt() -> Self {
        DevicePair { k: DeviceWindow::pjrt(), v: DeviceWindow::pjrt() }
    }

    /// Epoch the pair is current through (a lost half drags it to 0).
    pub fn epoch(&self) -> u64 {
        self.k.epoch().min(self.v.epoch())
    }

    pub fn supports_ranges(&self) -> bool {
        self.k.supports_ranges() && self.v.supports_ranges()
    }

    pub fn invalidate(&mut self) {
        self.k.invalidate();
        self.v.invalidate();
    }

    /// A delta upload against both resident buffers would be sound.
    pub fn can_delta(&self, host_len: usize) -> bool {
        self.k.can_delta(host_len) && self.v.can_delta(host_len)
    }

    /// Modeled ns both halves have spent receiving transfers.
    pub fn busy_ns(&self) -> u64 {
        self.k.busy_ns() + self.v.busy_ns()
    }
}

/// One staged upload handed to a transfer worker: the device pair
/// being staged plus the snapshot whose bytes it applies. The pair
/// travels *by ownership* — while the transfer is in flight nobody
/// else can touch (or observe a half-written) device buffer.
pub struct CopyJob {
    pub pair: DevicePair,
    pub snap: StagedUpload,
    /// Host window length the captured ranges index into.
    pub host_len: usize,
}

/// What comes back over a [`Fence`]: the device pair, whether the
/// captured ranges applied cleanly to both halves, the wall ns the
/// worker spent (including any simulated DMA busy time), and the
/// capture buffers for the window arena to recycle.
pub struct CopyDone {
    pub pair: DevicePair,
    /// False when a half refused the captured ranges (buffer lost
    /// between capture and apply) — the pair's epoch is stale and the
    /// caller must not rotate it in as staged.
    pub ok: bool,
    /// Wall-clock ns the worker spent applying this job.
    pub wall_ns: u64,
    pub k_data: Vec<f32>,
    pub v_data: Vec<f32>,
    pub ranges: Vec<(usize, usize)>,
}

/// The transfer worker (or this pool's lane) died with the job's
/// device pair.
#[derive(Debug)]
pub struct Poisoned;

/// Outcome of a watchdogged fence wait (DESIGN.md §11).
pub enum FenceWait {
    /// The transfer finished; the device pair is back.
    Done(CopyDone),
    /// The worker (or this pool's lane) died with the pair.
    Poisoned,
    /// The watchdog fired first: the worker still owns the pair
    /// (stalled transfer, saturated interconnect). The caller must
    /// abandon the pair and degrade — never wait unboundedly.
    TimedOut,
}

/// Completion ticket for one submitted [`CopyJob`].
pub struct Fence {
    rx: mpsc::Receiver<CopyDone>,
}

impl Fence {
    /// Block until the transfer finished (or the worker died). In
    /// steady pipelined decode the transfer completed under the
    /// previous execute and this returns immediately. Consumes the
    /// fence — the reply channel is one-shot, so there is no
    /// non-blocking probe to mix up with it.
    pub fn wait(self) -> Result<CopyDone, Poisoned> {
        self.rx.recv().map_err(|_| Poisoned)
    }

    /// [`wait`](Fence::wait) with a watchdog: a transfer that has not
    /// completed within `timeout` reports [`FenceWait::TimedOut`]
    /// instead of hanging the stage boundary. The fence is consumed
    /// either way; after a timeout the in-flight device pair stays
    /// with the worker (its eventual reply is dropped) and the caller
    /// rebuilds from a fresh pair, exactly like the poison path.
    pub fn wait_timeout(self, timeout: Duration) -> FenceWait {
        match self.rx.recv_timeout(timeout) {
            Ok(done) => FenceWait::Done(done),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                FenceWait::Poisoned
            }
            Err(mpsc::RecvTimeoutError::Timeout) => FenceWait::TimedOut,
        }
    }
}

enum WorkItem {
    // boxed: a CopyJob carries a device pair + capture buffers, far
    // larger than the poison marker
    Upload { job: Box<CopyJob>, reply: mpsc::Sender<CopyDone> },
    /// Test hook: makes the servicing worker panic, simulating a crash
    /// in the transfer path (poisoned-worker recovery coverage). On a
    /// dedicated stream the whole worker dies; on the shared engine
    /// the panic is caught and poisons only the submitting lane.
    Poison,
    /// Fault hook: the servicing worker sleeps this many ns before
    /// taking the next job — a transfer stall (interconnect spike)
    /// that the fence watchdog must bound (DESIGN.md §11).
    Stall(u64),
}

/// Submission-queue depth, per pool set. The pipeline keeps at most
/// one upload in flight per pool set, so 2 gives one slot of slack;
/// anything deeper only hides backpressure.
const QUEUE_DEPTH: usize = 2;

// ---------------------------------------------------------------------
// Shared multiplexed engine (DESIGN.md §10)
// ---------------------------------------------------------------------

/// One pool set's tagged submission lane on the shared engine.
#[derive(Default)]
struct PoolLane {
    queue: VecDeque<WorkItem>,
    /// A worker is servicing this lane right now — per-pool ordering:
    /// no second worker may pick the lane's next job until the current
    /// one finished.
    busy: bool,
    /// A panic while servicing this lane: submits are refused and the
    /// queued fences already failed; sibling lanes are untouched.
    poisoned: bool,
    /// The owning [`CopyStream`] handle dropped; the lane is removed
    /// once its queue drains.
    closed: bool,
    /// Peak outstanding jobs (queued + in service) observed — the
    /// per-pool backpressure ledger surfaced as the `copy_queue_peak`
    /// CSV column.
    peak: usize,
}

struct EngineState {
    /// Lane table; slots are reused so ids stay dense under pool-set
    /// churn (pipelines come and go in tests and multi-model serving).
    lanes: Vec<Option<PoolLane>>,
    /// Round-robin cursor: the next scan starts after the lane that
    /// was serviced last, so one pool's stream of large uploads cannot
    /// starve a sibling's.
    rr: usize,
    shutdown: bool,
}

impl EngineState {
    fn queued_total(&self) -> usize {
        self.lanes
            .iter()
            .flatten()
            .map(|l| l.queue.len())
            .sum()
    }

    /// Next serviceable job, round-robin across lanes. Skips busy
    /// lanes (per-pool ordering) and empty queues; a poisoned lane's
    /// queue is always empty (cleared at poison time).
    fn next_item(&mut self) -> Option<(usize, WorkItem)> {
        let n = self.lanes.len();
        for i in 0..n {
            let idx = (self.rr + i) % n;
            let Some(lane) = self.lanes[idx].as_mut() else {
                continue;
            };
            if lane.busy {
                continue;
            }
            if let Some(item) = lane.queue.pop_front() {
                lane.busy = true;
                self.rr = (idx + 1) % n;
                return Some((idx, item));
            }
        }
        None
    }
}

struct EngineCore {
    state: Mutex<EngineState>,
    /// Signalled when work arrives or a busy lane frees.
    work: Condvar,
    /// Signalled when a queue slot frees (submitter backpressure).
    slot: Condvar,
}

/// Owner of the shared workers; dropping the last [`CopyEngine`]
/// clone drains every lane and joins the workers.
struct EngineOwner {
    core: Arc<EngineCore>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for EngineOwner {
    fn drop(&mut self) {
        self.core.state.lock().unwrap().shutdown = true;
        self.core.work.notify_all();
        self.core.slot.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Shared multiplexed copy engine: one worker (or a small fixed pool)
/// owns a tagged submit queue interleaving [`CopyJob`]s from N
/// independent pool sets — round-robin fairness across lanes, bounded
/// per-lane backpressure, per-lane poison isolation (DESIGN.md §10).
/// Clone handles freely; the workers shut down (draining every queued
/// job first) when the last handle drops. [`CopyStream`] handles keep
/// working against a shut-down engine by refusing submits, which the
/// pipeline treats as a poison (inline staging).
#[derive(Clone)]
pub struct CopyEngine {
    owner: Arc<EngineOwner>,
}

impl CopyEngine {
    /// Spawn a shared engine with `workers` transfer threads (≥ 1).
    /// One worker already multiplexes fairly; more only help when the
    /// interconnect model allows genuinely parallel copies.
    pub fn new(workers: usize) -> Self {
        let core = Arc::new(EngineCore {
            state: Mutex::new(EngineState {
                lanes: Vec::new(),
                rr: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            slot: Condvar::new(),
        });
        let mut handles = Vec::new();
        for i in 0..workers.max(1) {
            let c = Arc::clone(&core);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pf-copy-engine-{i}"))
                    .spawn(move || shared_worker_loop(&c))
                    .expect("spawning shared copy-engine worker"),
            );
        }
        CopyEngine {
            owner: Arc::new(EngineOwner {
                core,
                workers: Mutex::new(handles),
            }),
        }
    }

    /// The process-wide shared engine (`--copy-engine shared`): every
    /// pool set in the process multiplexes through one worker. Never
    /// shut down — it lives as long as the process.
    pub fn global() -> &'static CopyEngine {
        static GLOBAL: OnceLock<CopyEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| CopyEngine::new(1))
    }

    /// Register one pool set: a tagged lane with its own bounded
    /// queue, fences, and poison state.
    pub fn stream(&self) -> CopyStream {
        let core = Arc::clone(&self.owner.core);
        let mut st = core.state.lock().unwrap();
        let pool = match st.lanes.iter().position(Option::is_none) {
            Some(i) => {
                st.lanes[i] = Some(PoolLane::default());
                i
            }
            None => {
                st.lanes.push(Some(PoolLane::default()));
                st.lanes.len() - 1
            }
        };
        drop(st);
        CopyStream { imp: StreamImpl::Shared { core, pool } }
    }

    /// Live (registered, not yet removed) lanes — tests assert lane
    /// slots are reused rather than leaked.
    pub fn pools(&self) -> usize {
        self.owner
            .core
            .state
            .lock()
            .unwrap()
            .lanes
            .iter()
            .flatten()
            .count()
    }
}

fn shared_worker_loop(core: &EngineCore) {
    loop {
        let next = {
            let mut st = core.state.lock().unwrap();
            loop {
                if let Some(x) = st.next_item() {
                    break Some(x);
                }
                if st.shutdown && st.queued_total() == 0 {
                    break None;
                }
                st = core.work.wait(st).unwrap();
            }
        };
        let Some((pool, item)) = next else { return };
        // popping the job already freed a queue slot — wake blocked
        // submitters now, not a whole transfer later
        core.slot.notify_all();
        // Panic isolation: a crash while servicing THIS lane (the
        // Poison test hook, or a real bug in the transfer path) must
        // not take the worker — and every other pool's lane — with it.
        let crashed = catch_unwind(AssertUnwindSafe(|| match item {
            WorkItem::Upload { job, reply } => {
                // a dropped fence (drain/shutdown race) is fine: the
                // transfer still completed, only nobody is listening
                let _ = reply.send(run_job(*job));
            }
            WorkItem::Poison => {
                panic!("copy engine poisoned while servicing a lane \
                        (test hook)");
            }
            WorkItem::Stall(ns) => {
                // injected interconnect spike: the lane (and, with
                // one worker, its siblings) stalls; the submitters'
                // fence watchdogs bound the damage
                std::thread::sleep(Duration::from_nanos(ns));
            }
        }))
        .is_err();
        let mut st = core.state.lock().unwrap();
        let remove = match st.lanes[pool].as_mut() {
            Some(lane) => {
                lane.busy = false;
                if crashed {
                    lane.poisoned = true;
                    // dropping the queued items drops their reply
                    // senders: every outstanding fence of THIS lane
                    // reports poison; sibling lanes never notice
                    lane.queue.clear();
                }
                lane.closed && lane.queue.is_empty()
            }
            None => false,
        };
        if remove {
            st.lanes[pool] = None;
        }
        drop(st);
        core.slot.notify_all();
        core.work.notify_all();
    }
}

// ---------------------------------------------------------------------
// Per-pool submission handle
// ---------------------------------------------------------------------

enum StreamImpl {
    /// PR 4 topology: this pool set owns a dedicated worker thread.
    Dedicated {
        tx: Option<mpsc::SyncSender<WorkItem>>,
        worker: Option<JoinHandle<()>>,
        /// Upload jobs submitted and not yet completed (the worker
        /// decrements after applying each one).
        depth: Arc<AtomicUsize>,
        peak: AtomicU64,
    },
    /// A tagged lane on the shared multiplexed engine.
    Shared { core: Arc<EngineCore>, pool: usize },
}

/// One pool set's transfer submission handle — a dedicated worker
/// thread ([`CopyStream::spawn`]) or a lane on the shared engine
/// ([`CopyEngine::stream`]). The submit/fence/poison API is identical
/// either way, so `engine::pipeline` is topology-blind.
pub struct CopyStream {
    imp: StreamImpl,
}

impl CopyStream {
    /// Dedicated transfer worker for this pool set alone.
    pub fn spawn() -> Self {
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(QUEUE_DEPTH);
        let depth = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&depth);
        let worker = std::thread::Builder::new()
            .name("pf-copy-stream".into())
            .spawn(move || dedicated_worker_loop(rx, &d))
            .expect("spawning copy-stream worker");
        CopyStream {
            imp: StreamImpl::Dedicated {
                tx: Some(tx),
                worker: Some(worker),
                depth,
                peak: AtomicU64::new(0),
            },
        }
    }

    /// Enqueue an upload; blocks when this pool's queue is full
    /// (backpressure). A dead worker — or a poisoned / shut-down lane
    /// — hands the job, and its device pair, straight back (boxed) so
    /// the caller can fall to the inline path without losing the
    /// buffer.
    pub fn submit(&self, job: CopyJob)
                  -> Result<Fence, Box<CopyJob>> {
        let (reply, rx) = mpsc::channel();
        let item = WorkItem::Upload { job: Box::new(job), reply };
        match &self.imp {
            StreamImpl::Dedicated { tx, depth, peak, .. } => {
                let d = depth.fetch_add(1, Ordering::Relaxed) + 1;
                peak.fetch_max(d as u64, Ordering::Relaxed);
                match tx
                    .as_ref()
                    .expect("copy stream submitted after shutdown")
                    .send(item)
                {
                    Ok(()) => Ok(Fence { rx }),
                    Err(mpsc::SendError(WorkItem::Upload {
                        job, ..
                    })) => {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        Err(job)
                    }
                    Err(mpsc::SendError(_)) => unreachable!(),
                }
            }
            StreamImpl::Shared { core, pool } => {
                let mut st = core.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return Err(unwrap_upload(item));
                    }
                    let Some(lane) = st.lanes[*pool].as_mut() else {
                        return Err(unwrap_upload(item));
                    };
                    if lane.poisoned {
                        return Err(unwrap_upload(item));
                    }
                    if lane.queue.len() < QUEUE_DEPTH {
                        lane.queue.push_back(item);
                        let outstanding =
                            lane.queue.len() + usize::from(lane.busy);
                        lane.peak = lane.peak.max(outstanding);
                        break;
                    }
                    st = core.slot.wait(st).unwrap();
                }
                drop(st);
                core.work.notify_one();
                Ok(Fence { rx })
            }
        }
    }

    /// Test hook: crash the transfer path after it drains what is
    /// already queued ahead. Dedicated: the worker thread dies and
    /// every later submit/fence reports poison. Shared: only THIS
    /// pool's lane is poisoned; sibling lanes keep their worker.
    pub fn inject_poison(&self) {
        match &self.imp {
            StreamImpl::Dedicated { tx, .. } => {
                if let Some(tx) = tx {
                    let _ = tx.send(WorkItem::Poison);
                }
            }
            StreamImpl::Shared { core, pool } => {
                let mut st = core.state.lock().unwrap();
                if let Some(lane) = st.lanes[*pool].as_mut() {
                    if !lane.poisoned {
                        lane.queue.push_back(WorkItem::Poison);
                    }
                }
                drop(st);
                core.work.notify_one();
            }
        }
    }

    /// Fault hook: the worker sleeps `ns` before servicing whatever
    /// is queued behind — a deterministic transfer stall. Later
    /// fences stay unanswered for the duration, which is exactly the
    /// condition [`Fence::wait_timeout`]'s watchdog must bound
    /// (DESIGN.md §11). On the shared engine the stall occupies the
    /// servicing worker (head-of-line, like a real interconnect
    /// spike); siblings' watchdogs bound it the same way.
    pub fn inject_stall(&self, ns: u64) {
        match &self.imp {
            StreamImpl::Dedicated { tx, .. } => {
                if let Some(tx) = tx {
                    let _ = tx.send(WorkItem::Stall(ns));
                }
            }
            StreamImpl::Shared { core, pool } => {
                let mut st = core.state.lock().unwrap();
                if let Some(lane) = st.lanes[*pool].as_mut() {
                    if !lane.poisoned {
                        lane.queue.push_back(WorkItem::Stall(ns));
                    }
                }
                drop(st);
                core.work.notify_one();
            }
        }
    }

    /// Peak outstanding jobs (submitted, not yet completed) observed
    /// for this pool set — the per-pool backpressure ledger
    /// (`copy_queue_peak` CSV column). Both topologies count the job
    /// in service, so the column is comparable across
    /// `--copy-engine` settings.
    pub fn queue_peak(&self) -> u64 {
        match &self.imp {
            StreamImpl::Dedicated { peak, .. } => {
                peak.load(Ordering::Relaxed)
            }
            StreamImpl::Shared { core, pool } => {
                let st = core.state.lock().unwrap();
                st.lanes[*pool]
                    .as_ref()
                    .map(|l| l.peak as u64)
                    .unwrap_or(0)
            }
        }
    }
}

fn unwrap_upload(item: WorkItem) -> Box<CopyJob> {
    match item {
        WorkItem::Upload { job, .. } => job,
        _ => unreachable!("only uploads are ever handed back"),
    }
}

impl Drop for CopyStream {
    fn drop(&mut self) {
        match &mut self.imp {
            StreamImpl::Dedicated { tx, worker, .. } => {
                // closing the queue lets the worker drain remaining
                // jobs and exit; join so no transfer outlives the
                // stream
                drop(tx.take());
                if let Some(h) = worker.take() {
                    let _ = h.join(); // a poisoned worker already unwound
                }
            }
            StreamImpl::Shared { core, pool } => {
                // mark the lane closed; queued jobs still complete
                // (and answer their fences) before the lane slot is
                // reused — the shared-engine clean-shutdown story
                let mut st = core.state.lock().unwrap();
                let remove = match st.lanes[*pool].as_mut() {
                    Some(lane) => {
                        lane.closed = true;
                        lane.queue.is_empty() && !lane.busy
                    }
                    None => false,
                };
                if remove {
                    st.lanes[*pool] = None;
                }
            }
        }
    }
}

fn dedicated_worker_loop(rx: mpsc::Receiver<WorkItem>,
                         depth: &AtomicUsize) {
    while let Ok(item) = rx.recv() {
        match item {
            WorkItem::Upload { job, reply } => {
                // a dropped fence (drain/shutdown race) is fine: the
                // transfer still completed, only nobody is listening
                let _ = reply.send(run_job(*job));
                // depth counts outstanding Upload jobs — submitted
                // and not yet completed — matching the shared lane's
                // queued + in-service accounting (the Poison/Stall
                // fault hooks never touch it)
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            WorkItem::Poison => {
                panic!("copy stream poisoned (test hook)");
            }
            WorkItem::Stall(ns) => {
                std::thread::sleep(Duration::from_nanos(ns));
            }
        }
    }
}

/// Apply one staged upload to both halves of the pair. Mirrors the
/// inline `TransferPipeline` staging path exactly — same captured-data
/// entry points, same failure semantics — so serial and threaded runs
/// produce identical device states.
fn run_job(mut job: CopyJob) -> CopyDone {
    let t = Instant::now();
    let snap = job.snap;
    let ok = if snap.full {
        job.pair.k.upload_full_captured(&snap.k_data, snap.through);
        job.pair.v.upload_full_captured(&snap.v_data, snap.through);
        true
    } else {
        let k_ok = job
            .pair
            .k
            .upload_captured(job.host_len, &snap.ranges, &snap.k_data,
                             snap.through)
            .is_ok();
        let v_ok = job
            .pair
            .v
            .upload_captured(job.host_len, &snap.ranges, &snap.v_data,
                             snap.through)
            .is_ok();
        k_ok && v_ok
    };
    CopyDone {
        pair: job.pair,
        ok,
        wall_ns: t.elapsed().as_nanos() as u64,
        k_data: snap.k_data,
        v_data: snap.v_data,
        ranges: snap.ranges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_snap(data: Vec<f32>, through: u64) -> StagedUpload {
        let mut snap = StagedUpload {
            through,
            full: true,
            ranges: Vec::new(),
            v_data: data.clone(),
            k_data: data,
            sum: 0,
        };
        snap.sum = snap.compute_sum();
        snap
    }

    fn zeroed_pair(len: usize) -> DevicePair {
        let mut pair = DevicePair::sim();
        pair.k.upload_full(&vec![0.0; len]);
        pair.v.upload_full(&vec![0.0; len]);
        pair
    }

    #[test]
    fn submit_wait_roundtrip_applies_the_upload() {
        let stream = CopyStream::spawn();
        let pair = zeroed_pair(16);

        let mut snap = StagedUpload {
            through: 7,
            full: false,
            ranges: vec![(4, 2)],
            k_data: vec![1.0, 2.0],
            v_data: vec![-1.0, -2.0],
            sum: 0,
        };
        snap.sum = snap.compute_sum();
        let Ok(fence) = stream.submit(CopyJob { pair, snap, host_len: 16 })
        else {
            panic!("live worker must accept jobs");
        };
        let done = fence.wait().expect("worker answers");
        assert!(done.ok);
        assert_eq!(done.pair.epoch(), 7, "epoch handoff rode the job");
        assert_eq!(&done.pair.k.contents().unwrap()[4..6], &[1.0, 2.0]);
        assert_eq!(&done.pair.v.contents().unwrap()[4..6],
                   &[-1.0, -2.0]);
        assert_eq!(done.k_data, vec![1.0, 2.0],
                   "capture buffers come back for the arena");
        assert!(stream.queue_peak() >= 1, "submission was accounted");
    }

    #[test]
    fn stale_pair_reports_not_ok_but_survives() {
        let stream = CopyStream::spawn();
        let pair = DevicePair::sim(); // never uploaded: can_delta false
        let mut snap = StagedUpload {
            through: 3,
            full: false,
            ranges: vec![(0, 1)],
            k_data: vec![1.0],
            v_data: vec![1.0],
            sum: 0,
        };
        snap.sum = snap.compute_sum();
        let Ok(fence) = stream.submit(CopyJob { pair, snap, host_len: 8 })
        else {
            panic!("live worker must accept jobs");
        };
        let done = fence.wait().unwrap();
        assert!(!done.ok, "captured ranges must refuse a lost buffer");
        assert_eq!(done.pair.epoch(), 0, "failed apply keeps the epoch");
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let stream = CopyStream::spawn();
        let mut fences = Vec::new();
        for i in 0..4u64 {
            let Ok(fence) = stream.submit(CopyJob {
                pair: zeroed_pair(8),
                snap: full_snap(vec![i as f32; 8], i + 1),
                host_len: 8,
            }) else {
                panic!("submit while live must succeed");
            };
            fences.push((i, fence));
        }
        drop(stream); // closes the queue, joins the worker
        for (i, fence) in fences {
            let done = fence.wait().expect("queued job drained");
            assert!(done.ok);
            assert_eq!(done.pair.k.contents().unwrap()[0], i as f32,
                       "job {i} applied before shutdown");
        }
    }

    #[test]
    fn poisoned_worker_fails_fences_and_submits() {
        let stream = CopyStream::spawn();
        stream.inject_poison();
        // whether a job lands before or after the worker unwinds, the
        // poison must surface within a bounded number of attempts —
        // either as a refused submit (pair handed back) or a dead fence
        let mut pair = Some(DevicePair::sim());
        let mut poisoned = false;
        for round in 0..50 {
            let job = CopyJob {
                pair: pair.take().unwrap(),
                snap: full_snap(vec![0.5; 4], round + 1),
                host_len: 4,
            };
            match stream.submit(job) {
                Err(job) => {
                    pair = Some(job.pair); // pair recovered intact
                    poisoned = true;
                    break;
                }
                Ok(fence) => match fence.wait() {
                    Err(Poisoned) => {
                        poisoned = true;
                        break;
                    }
                    Ok(done) => pair = Some(done.pair),
                },
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(poisoned, "poison never surfaced");
        drop(stream); // join of a panicked worker must not hang
    }

    #[test]
    fn fence_watchdog_bounds_a_stalled_transfer() {
        let stream = CopyStream::spawn();
        // stall the worker well past the watchdog, then queue a job
        stream.inject_stall(200_000_000); // 200 ms
        let Ok(fence) = stream.submit(CopyJob {
            pair: zeroed_pair(4),
            snap: full_snap(vec![1.0; 4], 1),
            host_len: 4,
        }) else {
            panic!("live worker must accept jobs");
        };
        let t = Instant::now();
        match fence.wait_timeout(Duration::from_millis(10)) {
            FenceWait::TimedOut => {}
            FenceWait::Done(_) => panic!("stalled job finished early"),
            FenceWait::Poisoned => panic!("stall is not a poison"),
        }
        assert!(t.elapsed() < Duration::from_millis(150),
                "watchdog must fire well before the stall clears");
        // an unstalled job + generous watchdog completes normally
        let Ok(fence) = stream.submit(CopyJob {
            pair: zeroed_pair(4),
            snap: full_snap(vec![2.0; 4], 1),
            host_len: 4,
        }) else {
            panic!("worker survives a stall");
        };
        match fence.wait_timeout(Duration::from_secs(10)) {
            FenceWait::Done(done) => {
                assert!(done.ok);
                assert_eq!(done.pair.k.contents().unwrap()[0], 2.0);
            }
            _ => panic!("healthy transfer must complete"),
        }
        drop(stream);
    }

    #[test]
    fn device_pair_epoch_is_min_of_halves() {
        let mut pair = DevicePair::sim();
        pair.k.upload_full(&[0.0; 4]);
        pair.v.upload_full(&[0.0; 4]);
        assert!(pair.supports_ranges());
        assert!(pair.can_delta(4));
        pair.v.invalidate();
        assert_eq!(pair.epoch(), 0, "lost half drags the pair to 0");
        assert!(!pair.can_delta(4));
    }

    // -----------------------------------------------------------------
    // shared multiplexed engine
    // -----------------------------------------------------------------

    #[test]
    fn shared_engine_multiplexes_independent_pools() {
        let engine = CopyEngine::new(1);
        let a = engine.stream();
        let b = engine.stream();
        assert_eq!(engine.pools(), 2);
        // interleave submissions from both pools through ONE worker;
        // each pool's uploads must land on its own pair, in order
        let mut fences = Vec::new();
        for round in 0..3u64 {
            for (tag, s) in [(10.0f32, &a), (20.0f32, &b)] {
                let Ok(f) = s.submit(CopyJob {
                    pair: zeroed_pair(8),
                    snap: full_snap(vec![tag + round as f32; 8],
                                    round + 1),
                    host_len: 8,
                }) else {
                    panic!("live lane must accept jobs");
                };
                fences.push((tag + round as f32, f));
            }
        }
        for (want, f) in fences {
            let done = f.wait().expect("lane answers");
            assert!(done.ok);
            assert_eq!(done.pair.k.contents().unwrap()[0], want,
                       "job applied to the right pool, in order");
        }
    }

    #[test]
    fn shared_lane_preserves_per_pool_order() {
        let engine = CopyEngine::new(2); // >1 worker: ordering must
                                         // come from the lane, not luck
        let s = engine.stream();
        let mut pair = zeroed_pair(4);
        for round in 1..=20u64 {
            let Ok(f) = s.submit(CopyJob {
                pair,
                snap: full_snap(vec![round as f32; 4], round),
                host_len: 4,
            }) else {
                panic!("live lane must accept jobs");
            };
            let done = f.wait().unwrap();
            assert_eq!(done.pair.epoch(), round,
                       "epochs must apply in submission order");
            pair = done.pair;
        }
    }

    #[test]
    fn shared_poison_isolates_the_lane() {
        let engine = CopyEngine::new(1);
        let a = engine.stream();
        let b = engine.stream();
        a.inject_poison();
        // pool A must observe the poison within bounded attempts...
        let mut pair = Some(DevicePair::sim());
        let mut poisoned = false;
        for round in 0..50 {
            let job = CopyJob {
                pair: pair.take().unwrap(),
                snap: full_snap(vec![0.5; 4], round + 1),
                host_len: 4,
            };
            match a.submit(job) {
                Err(job) => {
                    pair = Some(job.pair);
                    poisoned = true;
                    break;
                }
                Ok(fence) => match fence.wait() {
                    Err(Poisoned) => {
                        poisoned = true;
                        break;
                    }
                    Ok(done) => pair = Some(done.pair),
                },
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(poisoned, "lane poison never surfaced");
        // ...while pool B keeps its live worker throughout
        for round in 1..=5u64 {
            let Ok(f) = b.submit(CopyJob {
                pair: zeroed_pair(4),
                snap: full_snap(vec![round as f32; 4], round),
                host_len: 4,
            }) else {
                panic!("sibling lane must stay live after A's poison");
            };
            let done = f.wait().expect("sibling fence answers");
            assert!(done.ok);
            assert_eq!(done.pair.k.contents().unwrap()[0],
                       round as f32);
        }
    }

    #[test]
    fn engine_shutdown_drains_every_lane() {
        let engine = CopyEngine::new(1);
        let a = engine.stream();
        let b = engine.stream();
        let mut fences = Vec::new();
        for (tag, s) in [(1.0f32, &a), (2.0f32, &b)] {
            for i in 0..2u64 {
                let Ok(f) = s.submit(CopyJob {
                    pair: zeroed_pair(8),
                    snap: full_snap(vec![tag; 8], i + 1),
                    host_len: 8,
                }) else {
                    panic!("submit while live must succeed");
                };
                fences.push((tag, f));
            }
        }
        drop(engine); // last handle: drain all lanes, join the worker
        for (tag, f) in fences {
            let done = f.wait().expect("queued job drained at shutdown");
            assert!(done.ok);
            assert_eq!(done.pair.k.contents().unwrap()[0], tag);
        }
        // handles against the shut-down engine refuse politely: the
        // job (and pair) come back, like a dead dedicated worker
        let job = CopyJob {
            pair: DevicePair::sim(),
            snap: full_snap(vec![0.0; 4], 1),
            host_len: 4,
        };
        assert!(a.submit(job).is_err(),
                "submit after engine shutdown must hand the job back");
    }

    #[test]
    fn dropped_stream_frees_its_lane_slot_for_reuse() {
        let engine = CopyEngine::new(1);
        for _ in 0..8 {
            let s = engine.stream();
            // exercise the lane so drop also covers the drained path
            let Ok(f) = s.submit(CopyJob {
                pair: zeroed_pair(4),
                snap: full_snap(vec![1.0; 4], 1),
                host_len: 4,
            }) else {
                panic!("live lane must accept jobs");
            };
            f.wait().unwrap();
            drop(s);
        }
        // the worker clears a lane's busy flag just after answering
        // its fence, so the most recent lane (and at most one
        // straggler) may still be mid-removal — but the table must not
        // grow with the churn
        assert!(engine.pools() <= 2,
                "lane slots must be reused, not leaked: {}",
                engine.pools());
    }
}
