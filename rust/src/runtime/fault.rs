//! Deterministic fault-injection plans for the KV transfer stack
//! (DESIGN.md §11).
//!
//! A [`FaultPlan`] is a seed-reproducible schedule of fault events,
//! each pinned to a step index: copy-worker panics, device-buffer
//! loss, transfer stalls, allocation failures, failed executes. The
//! plan itself is pure data — *call sites* consume it through a
//! [`FaultInjector`] at their step boundaries and apply each event
//! with whatever mechanism that layer owns (`inject_poison`, buffer
//! `invalidate`, stalled jobs, refused reservations). The same plan
//! therefore drives both the real engine (`--fault-plan` /
//! `PF_FAULT_SEED`) and the offline chaos conformance suite, and a
//! given seed replays the identical schedule everywhere.

use crate::trace::Rng;
use crate::util::Result;
use crate::{bail, err};

/// One injectable failure mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Crash the pool's copy worker / shared-engine lane.
    WorkerPanic,
    /// Drop one half of a device buffer pair (loss mid-run).
    BufferLoss,
    /// Stall the in-flight transfer past the fence watchdog.
    Stall,
    /// Refuse the next page reservation (pool pressure spike).
    AllocFail,
    /// Fail the next execute (device-side launch failure).
    ExecFail,
}

impl FaultKind {
    pub const ALL: [FaultKind; 5] = [
        FaultKind::WorkerPanic,
        FaultKind::BufferLoss,
        FaultKind::Stall,
        FaultKind::AllocFail,
        FaultKind::ExecFail,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::WorkerPanic => "panic",
            FaultKind::BufferLoss => "loss",
            FaultKind::Stall => "stall",
            FaultKind::AllocFail => "alloc",
            FaultKind::ExecFail => "exec",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "panic" => Ok(FaultKind::WorkerPanic),
            "loss" => Ok(FaultKind::BufferLoss),
            "stall" => Ok(FaultKind::Stall),
            "alloc" => Ok(FaultKind::AllocFail),
            "exec" => Ok(FaultKind::ExecFail),
            other => Err(err!(
                "unknown fault kind '{other}' (want \
                 panic|loss|stall|alloc|exec)"
            )),
        }
    }
}

/// One scheduled fault: `kind` fires when the consumer reaches
/// step `step` (0-based, counted by the consuming layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// A full schedule, sorted by step. Cloneable pure data: hand the
/// same plan to two replicas and they see the same storm.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan — the zero-cost happy path.
    pub fn none() -> Self {
        FaultPlan { events: vec![] }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Seed-reproducible random schedule: `count` events uniformly
    /// over `[0, horizon)` steps, kinds drawn uniformly. The same
    /// seed always yields the same schedule (splitmix-seeded
    /// xoshiro, no ambient entropy).
    pub fn seeded(seed: u64, horizon: u64, count: usize) -> Self {
        let mut rng = Rng::seeded(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut events: Vec<FaultEvent> = (0..count)
            .map(|_| FaultEvent {
                step: rng.below(horizon.max(1)),
                kind: FaultKind::ALL
                    [rng.below(FaultKind::ALL.len() as u64) as usize],
            })
            .collect();
        events.sort_by_key(|e| e.step);
        FaultPlan { events }
    }

    /// Parse a `--fault-plan` spec. Two forms:
    ///
    /// * `seed:S` or `seed:S:HORIZON:COUNT` — a [`seeded`] plan
    ///   (defaults: horizon 240, count 12);
    /// * explicit comma list `kind@step,...`, e.g.
    ///   `panic@12,loss@30,stall@44,alloc@50,exec@61`.
    ///
    /// The empty string and `none` parse to the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::none());
        }
        if let Some(rest) = spec.strip_prefix("seed:") {
            let parts: Vec<&str> = rest.split(':').collect();
            let parse_u64 = |s: &str, what: &str| -> Result<u64> {
                s.parse::<u64>().map_err(|_| {
                    err!("fault plan: bad {what} '{s}' in '{spec}'")
                })
            };
            let seed = parse_u64(parts[0], "seed")?;
            let horizon = match parts.get(1) {
                Some(s) => parse_u64(s, "horizon")?,
                None => 240,
            };
            let count = match parts.get(2) {
                Some(s) => parse_u64(s, "count")? as usize,
                None => 12,
            };
            if parts.len() > 3 {
                bail!("fault plan: too many ':' fields in '{spec}'");
            }
            return Ok(FaultPlan::seeded(seed, horizon, count));
        }
        let mut events = vec![];
        for item in spec.split(',') {
            let item = item.trim();
            let (kind, step) = item.split_once('@').ok_or_else(|| {
                err!("fault plan item '{item}' is not 'kind@step'")
            })?;
            events.push(FaultEvent {
                step: step.parse::<u64>().map_err(|_| {
                    err!("fault plan: bad step '{step}' in '{item}'")
                })?,
                kind: FaultKind::parse(kind)?,
            });
        }
        events.sort_by_key(|e| e.step);
        Ok(FaultPlan { events })
    }

    /// `PF_FAULT_SEED=S` → the default seeded plan for `S`
    /// (horizon 240, count 12); unset/unparsable → `None`.
    pub fn from_env() -> Option<Self> {
        let seed = std::env::var("PF_FAULT_SEED")
            .ok()?
            .trim()
            .parse::<u64>()
            .ok()?;
        Some(FaultPlan::seeded(seed, 240, 12))
    }
}

/// Stateful cursor over a [`FaultPlan`]: the consuming layer calls
/// [`begin_step`](FaultInjector::begin_step) once per step and
/// applies whatever events fire. Steps past the horizon are clean —
/// recovery is always reachable.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    cursor: usize,
    step: u64,
    injected: u64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector { plan, cursor: 0, step: 0, injected: 0 }
    }

    /// An injector that never fires (the production default).
    pub fn idle() -> Self {
        FaultInjector::new(FaultPlan::none())
    }

    pub fn is_idle(&self) -> bool {
        self.plan.is_empty()
    }

    /// Events scheduled for the current step (may be several), in
    /// plan order. Advances the step counter.
    pub fn begin_step(&mut self) -> Vec<FaultKind> {
        let mut fired = vec![];
        while let Some(ev) = self.plan.events.get(self.cursor) {
            if ev.step > self.step {
                break;
            }
            fired.push(ev.kind);
            self.cursor += 1;
        }
        self.injected += fired.len() as u64;
        self.step += 1;
        fired
    }

    /// Total events delivered so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Steps consumed so far.
    pub fn step(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_replay_identically() {
        let a = FaultPlan::seeded(42, 100, 8);
        let b = FaultPlan::seeded(42, 100, 8);
        assert_eq!(a, b);
        assert_eq!(a.events().len(), 8);
        assert!(a.events().iter().all(|e| e.step < 100));
        assert!(a.events().windows(2).all(|w| w[0].step <= w[1].step));
        let c = FaultPlan::seeded(43, 100, 8);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn parse_explicit_list_sorts_by_step() {
        let p = FaultPlan::parse("loss@30, panic@12,exec@61").unwrap();
        let steps: Vec<u64> =
            p.events().iter().map(|e| e.step).collect();
        assert_eq!(steps, vec![12, 30, 61]);
        assert_eq!(p.events()[0].kind, FaultKind::WorkerPanic);
        assert_eq!(p.events()[1].kind, FaultKind::BufferLoss);
        assert_eq!(p.events()[2].kind, FaultKind::ExecFail);
    }

    #[test]
    fn parse_seed_form_and_empty() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        let p = FaultPlan::parse("seed:7").unwrap();
        assert_eq!(p, FaultPlan::seeded(7, 240, 12));
        let q = FaultPlan::parse("seed:7:50:3").unwrap();
        assert_eq!(q, FaultPlan::seeded(7, 50, 3));
        assert!(FaultPlan::parse("seed:x").is_err());
        assert!(FaultPlan::parse("panic@z").is_err());
        assert!(FaultPlan::parse("frob@3").is_err());
        assert!(FaultPlan::parse("panic-3").is_err());
    }

    #[test]
    fn injector_fires_at_scheduled_steps_then_goes_clean() {
        let plan =
            FaultPlan::parse("panic@1,loss@1,stall@3").unwrap();
        let mut inj = FaultInjector::new(plan);
        assert!(inj.begin_step().is_empty()); // step 0
        assert_eq!(
            inj.begin_step(),
            vec![FaultKind::WorkerPanic, FaultKind::BufferLoss]
        );
        assert!(inj.begin_step().is_empty()); // step 2
        assert_eq!(inj.begin_step(), vec![FaultKind::Stall]);
        for _ in 0..32 {
            assert!(inj.begin_step().is_empty(), "past the horizon");
        }
        assert_eq!(inj.injected(), 3);
        assert_eq!(inj.step(), 36);
    }

    #[test]
    fn past_due_events_fire_on_next_step() {
        // an injector built mid-run (step counter fresh) still
        // delivers every event exactly once
        let mut inj =
            FaultInjector::new(FaultPlan::parse("alloc@0").unwrap());
        assert_eq!(inj.begin_step(), vec![FaultKind::AllocFail]);
        assert!(inj.begin_step().is_empty());
        assert!(inj.is_idle() == false);
        assert!(FaultInjector::idle().is_idle());
    }
}
