//! Deterministic fault-injection plans for the KV transfer stack
//! (DESIGN.md §11).
//!
//! A [`FaultPlan`] is a seed-reproducible schedule of fault events,
//! each pinned to a step index: copy-worker panics, device-buffer
//! loss, transfer stalls, allocation failures, failed executes. The
//! plan itself is pure data — *call sites* consume it through a
//! [`FaultInjector`] at their step boundaries and apply each event
//! with whatever mechanism that layer owns (`inject_poison`, buffer
//! `invalidate`, stalled jobs, refused reservations). The same plan
//! therefore drives both the real engine (`--fault-plan` /
//! `PF_FAULT_SEED`) and the offline chaos conformance suite, and a
//! given seed replays the identical schedule everywhere.

use crate::trace::Rng;
use crate::util::Result;
use crate::{bail, err};

/// Where an injected KV corruption lands (DESIGN.md §14): the three
/// stations a page's bytes pass through on the host→device path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptTarget {
    /// Flip bits in a host pool page *without* restamping its
    /// checksum (models a torn sharded flush / stray write).
    HostPage,
    /// Flip bits in the staged snapshot after it was checksummed
    /// (models corruption in flight on the copy stream).
    StagedSnapshot,
    /// Flip bits in the live device window contents (models a
    /// device-side upset after a clean upload).
    DeviceWindow,
}

/// One injectable failure mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Crash the pool's copy worker / shared-engine lane.
    WorkerPanic,
    /// Drop one half of a device buffer pair (loss mid-run).
    BufferLoss,
    /// Stall the in-flight transfer past the fence watchdog.
    Stall,
    /// Refuse the next page reservation (pool pressure spike).
    AllocFail,
    /// Fail the next execute (device-side launch failure).
    ExecFail,
    /// Silently corrupt KV bytes at the given station.
    Corrupt(CorruptTarget),
}

impl FaultKind {
    /// The legacy draw table for `seed:` plans. Frozen at the PR 6
    /// set on purpose: widening it would silently reshuffle every
    /// existing seed's schedule (the CI chaos matrix pins seeds
    /// 3/17/29). Corruption-bearing schedules draw from
    /// [`ALL_WITH_CORRUPT`](Self::ALL_WITH_CORRUPT) via `cseed:`.
    pub const ALL: [FaultKind; 5] = [
        FaultKind::WorkerPanic,
        FaultKind::BufferLoss,
        FaultKind::Stall,
        FaultKind::AllocFail,
        FaultKind::ExecFail,
    ];

    /// The widened draw table — every legacy kind plus the three
    /// corruption targets — used only by [`FaultPlan::seeded_with_corrupt`]
    /// (`cseed:` specs), so legacy `seed:` schedules stay byte-stable.
    pub const ALL_WITH_CORRUPT: [FaultKind; 8] = [
        FaultKind::WorkerPanic,
        FaultKind::BufferLoss,
        FaultKind::Stall,
        FaultKind::AllocFail,
        FaultKind::ExecFail,
        FaultKind::Corrupt(CorruptTarget::HostPage),
        FaultKind::Corrupt(CorruptTarget::StagedSnapshot),
        FaultKind::Corrupt(CorruptTarget::DeviceWindow),
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::WorkerPanic => "panic",
            FaultKind::BufferLoss => "loss",
            FaultKind::Stall => "stall",
            FaultKind::AllocFail => "alloc",
            FaultKind::ExecFail => "exec",
            FaultKind::Corrupt(CorruptTarget::HostPage) => {
                "corrupt-host"
            }
            FaultKind::Corrupt(CorruptTarget::StagedSnapshot) => {
                "corrupt-stage"
            }
            FaultKind::Corrupt(CorruptTarget::DeviceWindow) => {
                "corrupt-device"
            }
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "panic" => Ok(FaultKind::WorkerPanic),
            "loss" => Ok(FaultKind::BufferLoss),
            "stall" => Ok(FaultKind::Stall),
            "alloc" => Ok(FaultKind::AllocFail),
            "exec" => Ok(FaultKind::ExecFail),
            "corrupt-host" => {
                Ok(FaultKind::Corrupt(CorruptTarget::HostPage))
            }
            "corrupt-stage" => {
                Ok(FaultKind::Corrupt(CorruptTarget::StagedSnapshot))
            }
            "corrupt-device" => {
                Ok(FaultKind::Corrupt(CorruptTarget::DeviceWindow))
            }
            other => Err(err!(
                "unknown fault kind '{other}' (want \
                 panic|loss|stall|alloc|exec|corrupt-host|\
                 corrupt-stage|corrupt-device)"
            )),
        }
    }
}

/// One scheduled fault: `kind` fires when the consumer reaches
/// step `step` (0-based, counted by the consuming layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// A full schedule, sorted by step. Cloneable pure data: hand the
/// same plan to two replicas and they see the same storm.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan — the zero-cost happy path.
    pub fn none() -> Self {
        FaultPlan { events: vec![] }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Seed-reproducible random schedule: `count` events uniformly
    /// over `[0, horizon)` steps, kinds drawn uniformly. The same
    /// seed always yields the same schedule (splitmix-seeded
    /// xoshiro, no ambient entropy).
    pub fn seeded(seed: u64, horizon: u64, count: usize) -> Self {
        let mut rng = Rng::seeded(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut events: Vec<FaultEvent> = (0..count)
            .map(|_| FaultEvent {
                step: rng.below(horizon.max(1)),
                kind: FaultKind::ALL
                    [rng.below(FaultKind::ALL.len() as u64) as usize],
            })
            .collect();
        events.sort_by_key(|e| e.step);
        FaultPlan { events }
    }

    /// [`seeded`](Self::seeded) over the widened
    /// [`FaultKind::ALL_WITH_CORRUPT`] table (the `cseed:` spec
    /// form). A distinct seed salt decorrelates it from the legacy
    /// stream, so `seed:S` schedules are untouched by the widening.
    pub fn seeded_with_corrupt(seed: u64, horizon: u64,
                               count: usize) -> Self {
        let mut rng = Rng::seeded(seed ^ 0xC0DE_FA17_C0DE_FA17);
        let table = FaultKind::ALL_WITH_CORRUPT;
        let mut events: Vec<FaultEvent> = (0..count)
            .map(|_| FaultEvent {
                step: rng.below(horizon.max(1)),
                kind: table
                    [rng.below(table.len() as u64) as usize],
            })
            .collect();
        events.sort_by_key(|e| e.step);
        FaultPlan { events }
    }

    /// Parse a `--fault-plan` spec. Three forms:
    ///
    /// * `seed:S` or `seed:S:HORIZON:COUNT` — a [`seeded`] plan
    ///   (defaults: horizon 240, count 12);
    /// * `cseed:S[:HORIZON[:COUNT]]` — same, drawing from the
    ///   widened corruption-bearing kind table
    ///   ([`seeded_with_corrupt`](Self::seeded_with_corrupt));
    /// * explicit comma list `kind@step,...`, e.g.
    ///   `panic@12,corrupt-host@30,stall@44,alloc@50,exec@61`.
    ///
    /// The empty string and `none` parse to the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::none());
        }
        let seeded_form = spec
            .strip_prefix("seed:")
            .map(|rest| (rest, false))
            .or_else(|| {
                spec.strip_prefix("cseed:").map(|rest| (rest, true))
            });
        if let Some((rest, with_corrupt)) = seeded_form {
            let parts: Vec<&str> = rest.split(':').collect();
            let parse_u64 = |s: &str, what: &str| -> Result<u64> {
                s.parse::<u64>().map_err(|_| {
                    err!("fault plan: bad {what} '{s}' in '{spec}'")
                })
            };
            let seed = parse_u64(parts[0], "seed")?;
            let horizon = match parts.get(1) {
                Some(s) => parse_u64(s, "horizon")?,
                None => 240,
            };
            let count = match parts.get(2) {
                Some(s) => parse_u64(s, "count")? as usize,
                None => 12,
            };
            if parts.len() > 3 {
                bail!("fault plan: too many ':' fields in '{spec}'");
            }
            return Ok(if with_corrupt {
                FaultPlan::seeded_with_corrupt(seed, horizon, count)
            } else {
                FaultPlan::seeded(seed, horizon, count)
            });
        }
        let mut events = vec![];
        for item in spec.split(',') {
            let item = item.trim();
            let (kind, step) = item.split_once('@').ok_or_else(|| {
                err!("fault plan item '{item}' is not 'kind@step'")
            })?;
            events.push(FaultEvent {
                step: step.parse::<u64>().map_err(|_| {
                    err!("fault plan: bad step '{step}' in '{item}'")
                })?,
                kind: FaultKind::parse(kind)?,
            });
        }
        events.sort_by_key(|e| e.step);
        Ok(FaultPlan { events })
    }

    /// `PF_FAULT_SEED=S` → the default seeded plan for `S`
    /// (horizon 240, count 12). Any non-numeric value is parsed as a
    /// full [`parse`](Self::parse) spec, so the CI matrix can pin
    /// corruption-bearing schedules (`PF_FAULT_SEED=cseed:41`)
    /// through the same variable. Unset / unparsable / empty →
    /// `None`.
    pub fn from_env() -> Option<Self> {
        let raw = std::env::var("PF_FAULT_SEED").ok()?;
        let raw = raw.trim();
        if let Ok(seed) = raw.parse::<u64>() {
            return Some(FaultPlan::seeded(seed, 240, 12));
        }
        FaultPlan::parse(raw).ok().filter(|p| !p.is_empty())
    }
}

/// Stateful cursor over a [`FaultPlan`]: the consuming layer calls
/// [`begin_step`](FaultInjector::begin_step) once per step and
/// applies whatever events fire. Steps past the horizon are clean —
/// recovery is always reachable.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    cursor: usize,
    step: u64,
    injected: u64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector { plan, cursor: 0, step: 0, injected: 0 }
    }

    /// An injector that never fires (the production default).
    pub fn idle() -> Self {
        FaultInjector::new(FaultPlan::none())
    }

    pub fn is_idle(&self) -> bool {
        self.plan.is_empty()
    }

    /// Events scheduled for the current step (may be several), in
    /// plan order. Advances the step counter.
    pub fn begin_step(&mut self) -> Vec<FaultKind> {
        let mut fired = vec![];
        while let Some(ev) = self.plan.events.get(self.cursor) {
            if ev.step > self.step {
                break;
            }
            fired.push(ev.kind);
            self.cursor += 1;
        }
        self.injected += fired.len() as u64;
        self.step += 1;
        fired
    }

    /// Total events delivered so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Steps consumed so far.
    pub fn step(&self) -> u64 {
        self.step
    }
}

// ----------------------------------------------------------------------
// serving-layer faults (DESIGN.md §12)
// ----------------------------------------------------------------------

/// One injectable *serving-tier* failure mode — faults that hit the
/// TCP/coordinator layer rather than the KV transfer stack. A
/// separate enum (not new [`FaultKind`] variants) on purpose:
/// `FaultPlan::seeded` draws kinds uniformly over `FaultKind::ALL`,
/// so widening that array would silently reshuffle every existing
/// seed's schedule (the CI chaos matrix pins seeds 3/17/29). The
/// PR 9 corruption kinds dodge the same hazard through the separate
/// [`FaultKind::ALL_WITH_CORRUPT`] table + `cseed:` spec form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingFaultKind {
    /// Client drops the connection mid-generate (reply send fails).
    ClientDisconnect,
    /// A burst of extra requests lands in one step (overload spike).
    Burst,
    /// A client stops reading / trickles bytes (read-timeout prey).
    SlowReader,
}

impl ServingFaultKind {
    pub const ALL: [ServingFaultKind; 3] = [
        ServingFaultKind::ClientDisconnect,
        ServingFaultKind::Burst,
        ServingFaultKind::SlowReader,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ServingFaultKind::ClientDisconnect => "disconnect",
            ServingFaultKind::Burst => "burst",
            ServingFaultKind::SlowReader => "slow",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "disconnect" => Ok(ServingFaultKind::ClientDisconnect),
            "burst" => Ok(ServingFaultKind::Burst),
            "slow" => Ok(ServingFaultKind::SlowReader),
            other => Err(err!(
                "unknown serving fault kind '{other}' (want \
                 disconnect|burst|slow)"
            )),
        }
    }
}

/// One scheduled serving fault (same step semantics as
/// [`FaultEvent`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServingFaultEvent {
    pub step: u64,
    pub kind: ServingFaultKind,
}

/// Seed-reproducible serving-fault schedule, the `serving_chaos`
/// mirror of [`FaultPlan`]. Distinct seed salt: the same numeric
/// seed drives *independent* engine and serving storms.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServingFaultPlan {
    events: Vec<ServingFaultEvent>,
}

impl ServingFaultPlan {
    pub fn none() -> Self {
        ServingFaultPlan { events: vec![] }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[ServingFaultEvent] {
        &self.events
    }

    /// `count` events uniformly over `[0, horizon)` steps, kinds
    /// uniform; same seed → same schedule.
    pub fn seeded(seed: u64, horizon: u64, count: usize) -> Self {
        let mut rng = Rng::seeded(seed ^ 0x5E12_11F0_5E12_11F0);
        let mut events: Vec<ServingFaultEvent> = (0..count)
            .map(|_| ServingFaultEvent {
                step: rng.below(horizon.max(1)),
                kind: ServingFaultKind::ALL[rng
                    .below(ServingFaultKind::ALL.len() as u64)
                    as usize],
            })
            .collect();
        events.sort_by_key(|e| e.step);
        ServingFaultPlan { events }
    }

    /// Parse `seed:S[:HORIZON[:COUNT]]` (defaults 120/8) or an
    /// explicit `kind@step,...` list; ``/`none` → empty.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(ServingFaultPlan::none());
        }
        if let Some(rest) = spec.strip_prefix("seed:") {
            let parts: Vec<&str> = rest.split(':').collect();
            let parse_u64 = |s: &str, what: &str| -> Result<u64> {
                s.parse::<u64>().map_err(|_| {
                    err!("serving fault plan: bad {what} '{s}' \
                          in '{spec}'")
                })
            };
            let seed = parse_u64(parts[0], "seed")?;
            let horizon = match parts.get(1) {
                Some(s) => parse_u64(s, "horizon")?,
                None => 120,
            };
            let count = match parts.get(2) {
                Some(s) => parse_u64(s, "count")? as usize,
                None => 8,
            };
            if parts.len() > 3 {
                bail!("serving fault plan: too many ':' fields \
                       in '{spec}'");
            }
            return Ok(ServingFaultPlan::seeded(seed, horizon, count));
        }
        let mut events = vec![];
        for item in spec.split(',') {
            let item = item.trim();
            let (kind, step) = item.split_once('@').ok_or_else(|| {
                err!("serving fault item '{item}' is not 'kind@step'")
            })?;
            events.push(ServingFaultEvent {
                step: step.parse::<u64>().map_err(|_| {
                    err!("serving fault plan: bad step '{step}' \
                          in '{item}'")
                })?,
                kind: ServingFaultKind::parse(kind)?,
            });
        }
        events.sort_by_key(|e| e.step);
        Ok(ServingFaultPlan { events })
    }
}

/// Stateful cursor over a [`ServingFaultPlan`] (same contract as
/// [`FaultInjector`]: one `begin_step` per serving step, clean past
/// the horizon).
#[derive(Debug, Clone)]
pub struct ServingFaultInjector {
    plan: ServingFaultPlan,
    cursor: usize,
    step: u64,
    injected: u64,
}

impl ServingFaultInjector {
    pub fn new(plan: ServingFaultPlan) -> Self {
        ServingFaultInjector { plan, cursor: 0, step: 0, injected: 0 }
    }

    pub fn idle() -> Self {
        ServingFaultInjector::new(ServingFaultPlan::none())
    }

    pub fn is_idle(&self) -> bool {
        self.plan.is_empty()
    }

    pub fn begin_step(&mut self) -> Vec<ServingFaultKind> {
        let mut fired = vec![];
        while let Some(ev) = self.plan.events.get(self.cursor) {
            if ev.step > self.step {
                break;
            }
            fired.push(ev.kind);
            self.cursor += 1;
        }
        self.injected += fired.len() as u64;
        self.step += 1;
        fired
    }

    pub fn injected(&self) -> u64 {
        self.injected
    }

    pub fn step(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_replay_identically() {
        let a = FaultPlan::seeded(42, 100, 8);
        let b = FaultPlan::seeded(42, 100, 8);
        assert_eq!(a, b);
        assert_eq!(a.events().len(), 8);
        assert!(a.events().iter().all(|e| e.step < 100));
        assert!(a.events().windows(2).all(|w| w[0].step <= w[1].step));
        let c = FaultPlan::seeded(43, 100, 8);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn parse_explicit_list_sorts_by_step() {
        let p = FaultPlan::parse("loss@30, panic@12,exec@61").unwrap();
        let steps: Vec<u64> =
            p.events().iter().map(|e| e.step).collect();
        assert_eq!(steps, vec![12, 30, 61]);
        assert_eq!(p.events()[0].kind, FaultKind::WorkerPanic);
        assert_eq!(p.events()[1].kind, FaultKind::BufferLoss);
        assert_eq!(p.events()[2].kind, FaultKind::ExecFail);
    }

    #[test]
    fn parse_seed_form_and_empty() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        let p = FaultPlan::parse("seed:7").unwrap();
        assert_eq!(p, FaultPlan::seeded(7, 240, 12));
        let q = FaultPlan::parse("seed:7:50:3").unwrap();
        assert_eq!(q, FaultPlan::seeded(7, 50, 3));
        assert!(FaultPlan::parse("seed:x").is_err());
        assert!(FaultPlan::parse("panic@z").is_err());
        assert!(FaultPlan::parse("frob@3").is_err());
        assert!(FaultPlan::parse("panic-3").is_err());
    }

    #[test]
    fn corrupt_kinds_roundtrip_and_parse_in_explicit_lists() {
        for kind in [
            FaultKind::Corrupt(CorruptTarget::HostPage),
            FaultKind::Corrupt(CorruptTarget::StagedSnapshot),
            FaultKind::Corrupt(CorruptTarget::DeviceWindow),
        ] {
            assert_eq!(FaultKind::parse(kind.as_str()).unwrap(), kind);
        }
        let p = FaultPlan::parse(
            "corrupt-device@9,corrupt-host@2, corrupt-stage@5",
        )
        .unwrap();
        let got: Vec<(u64, &str)> = p
            .events()
            .iter()
            .map(|e| (e.step, e.kind.as_str()))
            .collect();
        assert_eq!(
            got,
            vec![(2, "corrupt-host"), (5, "corrupt-stage"),
                 (9, "corrupt-device")]
        );
        assert!(FaultPlan::parse("corrupt@3").is_err(),
                "bare 'corrupt' must not parse");
    }

    #[test]
    fn cseed_widens_the_draw_table_and_leaves_seed_form_stable() {
        // legacy seed: schedules must not move — the CI chaos matrix
        // pins 3/17/29 against exactly these streams
        for seed in [3u64, 17, 29] {
            let p = FaultPlan::parse(&format!("seed:{seed}")).unwrap();
            assert_eq!(p, FaultPlan::seeded(seed, 240, 12));
            assert!(
                p.events().iter().all(|e| {
                    !matches!(e.kind, FaultKind::Corrupt(_))
                }),
                "seed: form must never draw corruption"
            );
        }
        // cseed: replays identically and reaches the widened table
        let c = FaultPlan::parse("cseed:41").unwrap();
        assert_eq!(c, FaultPlan::seeded_with_corrupt(41, 240, 12));
        assert_eq!(FaultPlan::parse("cseed:41:60:5").unwrap(),
                   FaultPlan::seeded_with_corrupt(41, 60, 5));
        let storm = FaultPlan::seeded_with_corrupt(41, 240, 48);
        assert!(
            storm.events().iter().any(|e| {
                matches!(e.kind, FaultKind::Corrupt(_))
            }),
            "a 48-event cseed storm must include corruption"
        );
        assert!(FaultPlan::parse("cseed:x").is_err());
        assert!(FaultPlan::parse("cseed:1:2:3:4").is_err());
    }

    #[test]
    fn injector_fires_at_scheduled_steps_then_goes_clean() {
        let plan =
            FaultPlan::parse("panic@1,loss@1,stall@3").unwrap();
        let mut inj = FaultInjector::new(plan);
        assert!(inj.begin_step().is_empty()); // step 0
        assert_eq!(
            inj.begin_step(),
            vec![FaultKind::WorkerPanic, FaultKind::BufferLoss]
        );
        assert!(inj.begin_step().is_empty()); // step 2
        assert_eq!(inj.begin_step(), vec![FaultKind::Stall]);
        for _ in 0..32 {
            assert!(inj.begin_step().is_empty(), "past the horizon");
        }
        assert_eq!(inj.injected(), 3);
        assert_eq!(inj.step(), 36);
    }

    #[test]
    fn serving_plans_replay_and_stay_independent_of_engine_plans() {
        let a = ServingFaultPlan::seeded(42, 64, 6);
        assert_eq!(a, ServingFaultPlan::seeded(42, 64, 6));
        assert_eq!(a.events().len(), 6);
        assert!(a.events().iter().all(|e| e.step < 64));
        assert!(a.events().windows(2).all(|w| w[0].step <= w[1].step));
        assert_ne!(a, ServingFaultPlan::seeded(43, 64, 6));
        // distinct salt: the engine plan for the same seed draws a
        // different stream (steps can't all coincide by construction)
        let eng = FaultPlan::seeded(42, 64, 6);
        let eng_steps: Vec<u64> =
            eng.events().iter().map(|e| e.step).collect();
        let srv_steps: Vec<u64> =
            a.events().iter().map(|e| e.step).collect();
        assert_ne!(eng_steps, srv_steps,
                   "serving salt must decorrelate the streams");
    }

    #[test]
    fn serving_plan_parses_both_forms() {
        assert!(ServingFaultPlan::parse("").unwrap().is_empty());
        assert!(ServingFaultPlan::parse("none").unwrap().is_empty());
        assert_eq!(ServingFaultPlan::parse("seed:9").unwrap(),
                   ServingFaultPlan::seeded(9, 120, 8));
        assert_eq!(ServingFaultPlan::parse("seed:9:40:2").unwrap(),
                   ServingFaultPlan::seeded(9, 40, 2));
        let p = ServingFaultPlan::parse(
            "slow@9, disconnect@2,burst@5").unwrap();
        let got: Vec<(u64, &str)> = p.events()
            .iter()
            .map(|e| (e.step, e.kind.as_str()))
            .collect();
        assert_eq!(got, vec![(2, "disconnect"), (5, "burst"),
                             (9, "slow")]);
        assert!(ServingFaultPlan::parse("seed:x").is_err());
        assert!(ServingFaultPlan::parse("frob@3").is_err());
        assert!(ServingFaultPlan::parse("slow-3").is_err());
    }

    #[test]
    fn serving_injector_fires_then_goes_clean() {
        let plan = ServingFaultPlan::parse(
            "disconnect@1,burst@1,slow@3").unwrap();
        let mut inj = ServingFaultInjector::new(plan);
        assert!(inj.begin_step().is_empty());
        assert_eq!(inj.begin_step(),
                   vec![ServingFaultKind::ClientDisconnect,
                        ServingFaultKind::Burst]);
        assert!(inj.begin_step().is_empty());
        assert_eq!(inj.begin_step(),
                   vec![ServingFaultKind::SlowReader]);
        for _ in 0..16 {
            assert!(inj.begin_step().is_empty());
        }
        assert_eq!(inj.injected(), 3);
        assert_eq!(inj.step(), 20);
        assert!(ServingFaultInjector::idle().is_idle());
        assert!(!inj.is_idle());
    }

    #[test]
    fn past_due_events_fire_on_next_step() {
        // an injector built mid-run (step counter fresh) still
        // delivers every event exactly once
        let mut inj =
            FaultInjector::new(FaultPlan::parse("alloc@0").unwrap());
        assert_eq!(inj.begin_step(), vec![FaultKind::AllocFail]);
        assert!(inj.begin_step().is_empty());
        assert!(inj.is_idle() == false);
        assert!(FaultInjector::idle().is_idle());
    }
}
