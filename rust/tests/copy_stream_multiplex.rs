//! Cross-pool stress & conformance suite for the shared multiplexed
//! copy engine (DESIGN.md §10).
//!
//! Randomized interleavings of 2–4 **independent pool sets** — each a
//! full kvpage state machine (manager, pools, resident window) with
//! its own admit/extend/decode/preempt/fork/buffer-loss traffic — all
//! submitting staged uploads through ONE shared [`CopyEngine`]. Every
//! pool set runs TWO replicas through the identical op sequence:
//!
//! * the **shared** replica stages through a tagged lane on the
//!   common engine (`TransferPipeline::sim_shared`);
//! * the **dedicated** replica stages through its own per-pool worker
//!   (`TransferPipeline::sim`, the PR 4 topology).
//!
//! At every execute boundary, each replica's FRONT device pair must be
//! element-identical to its pool for every mapped page, and — since
//! the replicas evolve through the same deterministic ops — the two
//! paths' device windows must be **byte-identical to each other**:
//! multiplexing N pools over one worker changes nothing observable
//! versus N dedicated workers.
//!
//! The poison test crashes ONE pool's lane mid-run: that pool must
//! demote to inline staging (poisons ≥ 1) without a divergent byte,
//! while every sibling pool keeps its live lane (poisons == 0) and
//! keeps staging on the shared worker. The shutdown test drops the
//! engine mid-run: every pool demotes inline and serving continues.
//!
//! `PF_COPY_THREADS` (the CI shared-engine stress job sets 4) shards
//! the shared replicas' gather AND write-through scatter, so the
//! suite also covers threaded host copies under multiplexing.

use std::sync::Arc;

use paged_flex::engine::pipeline::TransferPipeline;
use paged_flex::kvpage::{
    GrowthPolicy, HostPool, PageAllocator, PageManager, PoolGeometry,
    ResidentWindow,
};
use paged_flex::runtime::CopyEngine;
use paged_flex::trace::Rng;

const N_PAGES: u32 = 48;
const PAGE_SIZE: usize = 8;
const BYTES_PER_TOKEN: u64 = 16;
const MAX_BLOCKS: usize = 12;
const GEO: PoolGeometry = PoolGeometry {
    n_layers: 2,
    n_pages: N_PAGES as usize,
    page_size: PAGE_SIZE,
    n_kv_heads: 2,
    d_head: 4,
};
const BATCH_CAP: usize = 4;
const WINDOW_PAGES: usize = BATCH_CAP * MAX_BLOCKS;

fn env_copy_threads(default: usize) -> usize {
    std::env::var("PF_COPY_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// One replica of a pool set's full host-side decode state.
struct Replica {
    mgr: PageManager,
    k: HostPool,
    v: HostPool,
    win: ResidentWindow,
    pipe: TransferPipeline,
    counter: f32,
}

impl Replica {
    fn new(policy: GrowthPolicy, pipe: TransferPipeline,
           copy_threads: usize) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        let mut win = ResidentWindow::new(GEO);
        win.set_copy_threads(copy_threads);
        Replica {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            k: HostPool::zeros(GEO),
            v: HostPool::zeros(GEO),
            win,
            pipe,
            counter: 0.0,
        }
    }

    fn write_tokens(&mut self, id: u64, start: usize, n: usize) {
        let pages = self.mgr.table(id).unwrap().pages().to_vec();
        for pos in start..start + n {
            let (page, off) = (pages[pos / PAGE_SIZE], pos % PAGE_SIZE);
            for layer in 0..GEO.n_layers {
                self.counter += 1.0;
                self.k.token_row_mut(layer, page, off)
                    .fill(self.counter);
                self.v.token_row_mut(layer, page, off)
                    .fill(-self.counter);
            }
        }
    }
}

/// One pool set: the shared-engine replica `sh` and the
/// dedicated-worker replica `de`, plus the sequence population both
/// evolve through in lockstep.
struct PoolSet {
    sh: Replica,
    de: Replica,
    live: Vec<u64>,
    next_id: u64,
}

impl PoolSet {
    fn new(engine: &CopyEngine, policy: GrowthPolicy,
           copy_threads: usize) -> Self {
        PoolSet {
            sh: Replica::new(
                policy,
                TransferPipeline::sim_shared(engine, true),
                copy_threads,
            ),
            // the reference path: a dedicated worker, serial host
            // copies — the bit-for-bit baseline
            de: Replica::new(policy, TransferPipeline::sim(true), 1),
            live: vec![],
            next_id: 1,
        }
    }

    fn reserve_op(&mut self, rng: &mut Rng) {
        let id = self.next_id;
        let len = 1 + rng.below(60) as usize;
        let prompt: Vec<u32> =
            (0..len).map(|_| rng.below(512) as u32).collect();
        let a = self.sh.mgr.reserve(id, &prompt);
        let b = self.de.mgr.reserve(id, &prompt);
        match (a, b) {
            (Ok(oa), Ok(ob)) => {
                assert_eq!(oa.cached_tokens, ob.cached_tokens,
                           "replicas diverged on admission");
                self.next_id += 1;
                self.live.push(id);
                let fresh = prompt.len() - oa.cached_tokens;
                self.sh.write_tokens(id, oa.cached_tokens, fresh);
                self.de.write_tokens(id, ob.cached_tokens, fresh);
                self.sh.mgr.note_assigned(id, fresh).unwrap();
                self.de.mgr.note_assigned(id, fresh).unwrap();
                if rng.below(2) == 0 {
                    self.sh.mgr.register_prefix(id, &prompt).unwrap();
                    self.de.mgr.register_prefix(id, &prompt).unwrap();
                }
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on reserve outcome"),
        }
    }

    fn append_op(&mut self, rng: &mut Rng) {
        if self.live.is_empty() {
            return;
        }
        let id = self.live[rng.below(self.live.len() as u64) as usize];
        let extra = 1 + rng.below(10) as usize;
        let a = self.sh.mgr.prepare_append(id, extra);
        let b = self.de.mgr.prepare_append(id, extra);
        match (a, b) {
            (Ok(pa), Ok(pb)) => {
                if let Some((src, dst)) = pa.cow_copy {
                    self.sh.k.copy_page(src, dst);
                    self.sh.v.copy_page(src, dst);
                }
                if let Some((src, dst)) = pb.cow_copy {
                    self.de.k.copy_page(src, dst);
                    self.de.v.copy_page(src, dst);
                }
                let len = self.sh.mgr.seq_len(id).unwrap();
                self.sh.write_tokens(id, len, extra);
                self.de.write_tokens(id, len, extra);
                self.sh.mgr.note_assigned(id, extra).unwrap();
                self.de.mgr.note_assigned(id, extra).unwrap();
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on append outcome"),
        }
    }

    fn fork_op(&mut self, rng: &mut Rng) {
        if self.live.is_empty() {
            return;
        }
        let parent =
            self.live[rng.below(self.live.len() as u64) as usize];
        let plen = self.sh.mgr.seq_len(parent).unwrap();
        if plen == 0 {
            return;
        }
        let at = 1 + rng.below(plen as u64) as usize;
        let child = self.next_id;
        let a = self.sh.mgr.fork(parent, child, at);
        let b = self.de.mgr.fork(parent, child, at);
        match (a, b) {
            (Ok(pa), Ok(pb)) => {
                if let Some((src, dst)) = pa.cow_copy {
                    self.sh.k.copy_page(src, dst);
                    self.sh.v.copy_page(src, dst);
                }
                if let Some((src, dst)) = pb.cow_copy {
                    self.de.k.copy_page(src, dst);
                    self.de.v.copy_page(src, dst);
                }
                self.next_id += 1;
                self.live.push(child);
                // engine forks drain; exercise both interleavings —
                // the epoch protocol keeps the undrained one sound
                if rng.below(2) == 0 {
                    self.sh.pipe.drain();
                    self.de.pipe.drain();
                }
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on fork outcome"),
        }
    }

    fn free_op(&mut self, rng: &mut Rng, preempt: bool) {
        if self.live.is_empty() {
            return;
        }
        let i = rng.below(self.live.len() as u64) as usize;
        let id = self.live.swap_remove(i);
        for page in self.sh.mgr.free(id).unwrap() {
            self.sh.win.forget(page);
        }
        for page in self.de.mgr.free(id).unwrap() {
            self.de.win.forget(page);
        }
        if preempt {
            // engine preemption: residency dropped, staged drained
            self.sh.win.invalidate();
            self.de.win.invalidate();
            self.sh.pipe.drain();
            self.de.pipe.drain();
        }
    }

    fn decode_step_op(&mut self, rng: &mut Rng, ctx: &str) {
        let mut batch: Vec<u64> = vec![];
        let want = 1 + rng.below(BATCH_CAP as u64) as usize;
        for _ in 0..want {
            if self.live.is_empty() {
                break;
            }
            let id =
                self.live[rng.below(self.live.len() as u64) as usize];
            if !batch.contains(&id) {
                batch.push(id);
            }
        }
        // independent device-buffer loss per replica: contents must
        // still match the pools (and therefore each other) after the
        // full-upload recoveries
        if rng.below(16) == 0 {
            self.sh.pipe.front_mut().k.invalidate();
        }
        if rng.below(16) == 0 {
            self.sh.pipe.back_mut().v.invalidate();
        }
        if rng.below(16) == 0 {
            self.de.pipe.front_mut().v.invalidate();
        }
        batch.retain(|&id| {
            let a = self.sh.mgr.prepare_append(id, 1);
            let b = self.de.mgr.prepare_append(id, 1);
            match (a, b) {
                (Ok(pa), Ok(pb)) => {
                    if let Some((src, dst)) = pa.cow_copy {
                        self.sh.k.copy_page(src, dst);
                        self.sh.v.copy_page(src, dst);
                    }
                    if let Some((src, dst)) = pb.cow_copy {
                        self.de.k.copy_page(src, dst);
                        self.de.v.copy_page(src, dst);
                    }
                    true
                }
                (Err(_), Err(_)) => false,
                _ => panic!("{ctx}: replicas diverged on append"),
            }
        });
        if batch.is_empty() {
            return;
        }

        // both replicas run the engine's stage boundaries
        let mut mapped: Vec<(u64, Vec<u32>)> = vec![];
        for &id in &batch {
            let len = self.sh.mgr.seq_len(id).unwrap();
            let pages = self
                .sh
                .mgr
                .table(id)
                .unwrap()
                .blocks_covering(len + 1)
                .to_vec();
            mapped.push((id, pages));
        }
        for r in [&mut self.sh, &mut self.de] {
            r.pipe.begin_step(&mut r.win);
            r.win.begin_step(WINDOW_PAGES);
            for (_, pages) in &mapped {
                for &pg in pages {
                    r.win
                        .map_page(&mut r.k, &mut r.v, pg)
                        .expect("window slots exhausted");
                }
            }
            r.win.flush_pending(&r.k, &r.v);
            r.pipe.pre_execute(&mut r.win);
        }

        self.verify(ctx, &mapped);
        for r in [&mut self.sh, &mut self.de] {
            r.pipe.note_execute(1_000_000);
        }

        // scatter one token per sequence with write-through, both
        // replicas (identical values: counters advance in lockstep)
        for &id in &batch {
            let len = self.sh.mgr.seq_len(id).unwrap();
            for r in [&mut self.sh, &mut self.de] {
                let pages = r.mgr.table(id).unwrap().pages().to_vec();
                let (page, off) =
                    (pages[len / PAGE_SIZE], len % PAGE_SIZE);
                for layer in 0..GEO.n_layers {
                    r.counter += 1.0;
                    r.k.token_row_mut(layer, page, off)
                        .fill(r.counter);
                    r.v.token_row_mut(layer, page, off)
                        .fill(-r.counter);
                    r.win.write_row(&mut r.k, &mut r.v, layer, page,
                                    off);
                }
                r.mgr.note_assigned(id, 1).unwrap();
            }
        }
        // deferred-mode flush (no-op at copy_threads 1)
        self.sh.win.flush_rows(&self.sh.k, &self.sh.v);
        self.de.win.flush_rows(&self.de.k, &self.de.v);
    }

    /// Execute-boundary equivalence: each replica's FRONT device pair
    /// equals its pool for every mapped page — and the shared-engine
    /// path's device bytes equal the dedicated-worker path's.
    fn verify(&self, ctx: &str, mapped: &[(u64, Vec<u32>)]) {
        let pe = GEO.page_elems();
        let shk = self.sh.pipe.front().k.contents()
            .expect("shared front K resident after pre_execute");
        let shv = self.sh.pipe.front().v.contents()
            .expect("shared front V resident after pre_execute");
        let dek = self.de.pipe.front().k.contents()
            .expect("dedicated front K resident after pre_execute");
        let dev = self.de.pipe.front().v.contents()
            .expect("dedicated front V resident after pre_execute");
        for (id, pages) in mapped {
            for &p in pages {
                let ss = self.sh.win.slot(p).unwrap() as usize;
                let ds = self.de.win.slot(p).unwrap() as usize;
                assert_eq!(ss, ds,
                           "{ctx}: seq {id} page {p}: replicas \
                            diverged on slot assignment");
                for layer in 0..GEO.n_layers {
                    let src = GEO.offset(layer, p, 0);
                    let kp = &self.sh.k.as_slice()[src..src + pe];
                    let vp = &self.sh.v.as_slice()[src..src + pe];
                    let off = (layer * WINDOW_PAGES + ss) * pe;
                    assert_eq!(&shk[off..off + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: shared-engine device stale");
                    assert_eq!(&shv[off..off + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: shared-engine device stale");
                    assert_eq!(&dek[off..off + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: dedicated device stale");
                    assert_eq!(&dev[off..off + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: dedicated device stale");
                    assert_eq!(&shk[off..off + pe], &dek[off..off + pe],
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: shared vs dedicated bytes \
                                diverged");
                }
            }
        }
    }

    fn step(&mut self, rng: &mut Rng, ctx: &str) {
        match rng.below(10) {
            0..=2 => self.reserve_op(rng),
            3 => self.append_op(rng),
            4 => self.fork_op(rng),
            5 => self.free_op(rng, false),
            6 => self.free_op(rng, true),
            _ => self.decode_step_op(rng, ctx),
        }
    }

    fn drain_all(&mut self, rng: &mut Rng) {
        while !self.live.is_empty() {
            self.free_op(rng, false);
        }
    }
}

struct MultiHarness {
    engine: CopyEngine,
    pools: Vec<PoolSet>,
    rng: Rng,
}

impl MultiHarness {
    fn new(seed: u64, n_pools: usize, copy_threads: usize) -> Self {
        let engine = CopyEngine::new(1);
        let pools = (0..n_pools)
            .map(|i| {
                let policy = if i % 2 == 0 {
                    GrowthPolicy::Exact
                } else {
                    GrowthPolicy::PowerOfTwo
                };
                PoolSet::new(&engine, policy, copy_threads)
            })
            .collect();
        MultiHarness { engine, pools, rng: Rng::seeded(seed) }
    }

    /// One harness step: a random pool set takes a random op, so the
    /// shared worker sees genuinely interleaved traffic.
    fn step(&mut self, step: usize, ctx_tag: &str) {
        let p = self.rng.below(self.pools.len() as u64) as usize;
        let ctx = format!("{ctx_tag} step {step} pool {p}");
        self.pools[p].step(&mut self.rng, &ctx);
    }
}

#[test]
fn multiplexed_pools_match_dedicated_workers_random_interleavings() {
    let threads = env_copy_threads(2);
    for seed in 0..6u64 {
        let n_pools = 2 + (seed % 3) as usize; // 2–4 pool sets
        let mut h = MultiHarness::new(5000 + seed, n_pools, threads);
        for step in 0..160 {
            h.step(step, &format!("seed {seed} ({n_pools} pools)"));
        }
        // force at least one verified decode per pool so the staging
        // assertions below never depend on the random op mix
        for (i, p) in h.pools.iter_mut().enumerate() {
            let mut rng = Rng::seeded(seed * 31 + i as u64);
            let ctx = format!("seed {seed} forced decode pool {i}");
            p.reserve_op(&mut rng);
            p.decode_step_op(&mut rng, &ctx);
            p.decode_step_op(&mut rng, &ctx);
        }
        for (i, p) in h.pools.iter_mut().enumerate() {
            let mut rng = Rng::seeded(seed);
            p.drain_all(&mut rng);
            assert_eq!(p.sh.mgr.allocator().free_pages(),
                       N_PAGES as usize,
                       "seed {seed} pool {i}: shared replica leaked");
            assert_eq!(p.de.mgr.allocator().free_pages(),
                       N_PAGES as usize,
                       "seed {seed} pool {i}: dedicated replica leaked");
            assert_eq!(p.sh.pipe.stats().poisons, 0,
                       "seed {seed} pool {i}: unexpected lane poison");
            assert!(p.sh.pipe.stats().staged_uploads > 0,
                    "seed {seed} pool {i}: shared lane never staged");
        }
        assert!(h.engine.pools() <= n_pools,
                "seed {seed}: lane table leaked ({} lanes for \
                 {n_pools} pools)", h.engine.pools());
    }
}

#[test]
fn poisoned_pool_demotes_inline_while_siblings_stay_live() {
    let threads = env_copy_threads(2);
    for seed in 20..23u64 {
        let mut h = MultiHarness::new(6000 + seed, 3, threads);
        let mut wall_before_poison = 0;
        for step in 0..220 {
            if step == 60 {
                // crash pool 0's lane on the shared engine mid-run
                h.pools[0].sh.pipe.poison_stream_for_test();
                wall_before_poison = h.pools[1]
                    .sh
                    .pipe
                    .stats()
                    .measured_wall_ns;
            }
            h.step(step, &format!("poison seed {seed}"));
        }
        // drive every pool through a few deterministic decode steps so
        // the post-poison behaviour is observed on each of them
        for p in 0..3usize {
            for extra in 0..6 {
                let ctx = format!("poison seed {seed} tail {extra} \
                                   pool {p}");
                let mut rng = Rng::seeded(seed * 97 + extra);
                h.pools[p].reserve_op(&mut rng);
                h.pools[p].decode_step_op(&mut rng, &ctx);
            }
        }
        let poisoned = h.pools[0].sh.pipe.stats();
        assert!(poisoned.poisons >= 1,
                "seed {seed}: pool 0's lane poison never surfaced \
                 ({poisoned:?})");
        for (i, p) in h.pools.iter().enumerate().skip(1) {
            let s = p.sh.pipe.stats();
            assert_eq!(s.poisons, 0,
                       "seed {seed}: sibling pool {i} observed the \
                        poison ({s:?})");
            assert!(s.measured_wall_ns > wall_before_poison,
                    "seed {seed}: sibling pool {i} stopped staging on \
                     the shared worker after the poison ({s:?})");
        }
    }
}

#[test]
fn engine_shutdown_mid_run_demotes_every_pool_inline() {
    let mut h = MultiHarness::new(7000, 2, 1);
    for step in 0..60 {
        h.step(step, "pre-shutdown");
    }
    // drop the engine while the pools still serve: lanes drain, then
    // every submit is refused — each pool demotes to inline staging
    // (counted as a poison) and keeps byte-identical device contents
    let engine = std::mem::replace(&mut h.engine, CopyEngine::new(1));
    drop(engine);
    for step in 60..140 {
        h.step(step, "post-shutdown");
    }
    for (i, p) in h.pools.iter_mut().enumerate() {
        let mut rng = Rng::seeded(42 + i as u64);
        for extra in 0..4 {
            let ctx = format!("post-shutdown tail {extra} pool {i}");
            p.reserve_op(&mut rng);
            p.decode_step_op(&mut rng, &ctx);
        }
        let s = p.sh.pipe.stats();
        assert!(s.poisons >= 1,
                "pool {i} never noticed the engine shutdown ({s:?})");
        assert!(s.staged_uploads > 0,
                "pool {i} must keep staging inline ({s:?})");
    }
}
