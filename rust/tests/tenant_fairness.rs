//! Seeded property sweeps over the per-class scheduling policy
//! (coordinator::tenant, DESIGN.md §13) — the in-tree PRNG stands in
//! for proptest (offline container, no new crates):
//!
//!   * weighted DRR is starvation-free: under sustained backlog every
//!     class with weight > 0 pops again within one full weight cycle;
//!   * DRR shares service proportionally to configured weights;
//!   * EDF never admits a later-deadline item ahead of an earlier one
//!     drained within the same tick, and breaks ties stably;
//!   * shed victims always come from the cheapest backlogged class,
//!     newest first;
//!   * queue bookkeeping (lengths, drains) stays consistent under
//!     randomized interleavings of push/pop/shed.

use paged_flex::coordinator::{ClassQueues, Popped};
use paged_flex::trace::Rng;

/// Random class count (2..=4) and weights (1..=7) from `rng`.
fn random_weights(rng: &mut Rng) -> Vec<u32> {
    let n = 2 + rng.below(3) as usize;
    (0..n).map(|_| 1 + rng.below(7) as u32).collect()
}

#[test]
fn drr_is_starvation_free_under_sustained_backlog() {
    for seed in 0..20u64 {
        let mut rng = Rng::seeded(0xFA1A_0000 + seed);
        let weights = random_weights(&mut rng);
        let cycle: u64 = weights.iter().map(|&w| w as u64).sum();
        let mut q: ClassQueues<u64> = ClassQueues::new(&weights);
        // keep every queue backlogged the whole time
        for c in 0..weights.len() {
            for i in 0..64u64 {
                q.push_back(c, i);
            }
        }
        let mut last_seen = vec![0u64; weights.len()];
        for pop in 0..(4 * cycle) {
            let Popped::Item { class, .. } = q.pop_drr(|_| true)
            else {
                panic!("seed {seed}: backlogged queues went empty");
            };
            q.push_back(class, pop); // keep it backlogged
            let gap = pop - last_seen[class];
            assert!(gap <= cycle,
                    "seed {seed}: class {class} (weights \
                     {weights:?}) waited {gap} pops, cycle {cycle}");
            last_seen[class] = pop;
        }
    }
}

#[test]
fn drr_service_share_tracks_weights_exactly() {
    for seed in 0..20u64 {
        let mut rng = Rng::seeded(0xFA1A_1000 + seed);
        let weights = random_weights(&mut rng);
        let cycle: usize =
            weights.iter().map(|&w| w as usize).sum();
        let mut q: ClassQueues<usize> = ClassQueues::new(&weights);
        for c in 0..weights.len() {
            for i in 0..512 {
                q.push_back(c, i);
            }
        }
        // whole cycles over fully-backlogged queues give each class
        // exactly `weight` pops per cycle — no drift, no bias
        let rounds = 10;
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..(rounds * cycle) {
            match q.pop_drr(|_| true) {
                Popped::Item { class, .. } => counts[class] += 1,
                other => panic!("seed {seed}: {other:?}"),
            }
        }
        for (c, &w) in weights.iter().enumerate() {
            assert_eq!(counts[c], rounds * w as usize,
                       "seed {seed}: class {c} of {weights:?} got \
                        {counts:?}");
        }
    }
}

#[test]
fn edf_drains_in_deadline_order_within_a_tick() {
    for seed in 0..30u64 {
        let mut rng = Rng::seeded(0xEDF_2000 + seed);
        let weights = random_weights(&mut rng);
        let mut q: ClassQueues<(u64, u64)> =
            ClassQueues::new(&weights);
        let n = 16 + rng.below(48);
        for i in 0..n {
            let class = rng.below(weights.len() as u64) as usize;
            let deadline = rng.below(40); // dense → many ties
            q.push_back(class, (deadline, i));
        }
        // one "tick": drain everything by EDF; the admitted
        // deadline sequence must never decrease (no inversion)
        let mut prev: Option<(u64, u64)> = None;
        while let Popped::Item { item, .. } =
            q.pop_edf(|_| true, |&(d, _)| d)
        {
            if let Some((pd, pi)) = prev {
                assert!(item.0 >= pd,
                        "seed {seed}: deadline {} admitted after \
                         {pd} (items {pi} then {})", item.0, item.1);
            }
            prev = Some(item);
        }
        assert!(q.is_empty());
    }
}

#[test]
fn edf_tie_break_is_stable_within_a_class() {
    // equal deadlines in one class must drain in arrival order
    let mut q: ClassQueues<(u64, u64)> = ClassQueues::new(&[1, 1]);
    for i in 0..8u64 {
        q.push_back(0, (5, i));
    }
    let mut seen = Vec::new();
    while let Popped::Item { item, .. } =
        q.pop_edf(|_| true, |&(d, _)| d)
    {
        seen.push(item.1);
    }
    assert_eq!(seen, (0..8).collect::<Vec<u64>>(),
               "equal-deadline items must keep arrival order");
}

#[test]
fn shed_victims_are_newest_of_the_cheapest_backlogged_class() {
    for seed in 0..20u64 {
        let mut rng = Rng::seeded(0x5EED_3000 + seed);
        let weights = random_weights(&mut rng);
        let mut q: ClassQueues<u64> = ClassQueues::new(&weights);
        let mut tails: Vec<Vec<u64>> =
            vec![Vec::new(); weights.len()];
        let n = 8 + rng.below(40);
        for i in 0..n {
            let class = rng.below(weights.len() as u64) as usize;
            q.push_back(class, i);
            tails[class].push(i);
        }
        while let Some((class, item)) = q.pop_shed_newest() {
            let w = weights[class];
            for (c, t) in tails.iter().enumerate() {
                if !t.is_empty() {
                    assert!(weights[c] >= w,
                            "seed {seed}: shed from weight-{w} \
                             class {class} while cheaper class {c} \
                             (weight {}) was backlogged",
                            weights[c]);
                }
            }
            let expect = tails[class].pop().unwrap();
            assert_eq!(item, expect,
                       "seed {seed}: victim must be the newest of \
                        class {class}");
        }
        assert!(tails.iter().all(|t| t.is_empty()));
    }
}

#[test]
fn bookkeeping_survives_randomized_interleavings() {
    for seed in 0..10u64 {
        let mut rng = Rng::seeded(0xB00C_4000 + seed);
        let weights = random_weights(&mut rng);
        let mut q: ClassQueues<u64> = ClassQueues::new(&weights);
        let mut alive = 0usize;
        for op in 0..400u64 {
            match rng.below(4) {
                0 | 1 => {
                    let c = rng.below(weights.len() as u64) as usize;
                    q.push_back(c, op);
                    alive += 1;
                }
                2 => {
                    if let Popped::Item { .. } = q.pop_drr(|_| true) {
                        alive -= 1;
                    }
                }
                _ => {
                    if q.pop_shed_newest().is_some() {
                        alive -= 1;
                    }
                }
            }
            assert_eq!(q.len(), alive, "seed {seed} op {op}");
            let by_class: usize = (0..q.n_classes())
                .map(|c| q.class_len(c))
                .sum();
            assert_eq!(by_class, alive,
                       "seed {seed} op {op}: per-class lengths \
                        disagree with the total");
        }
        let drained = q.drain_all();
        assert_eq!(drained.len(), alive,
                   "drain_all must return every queued item");
        assert!(q.is_empty());
    }
}
