//! Chaos conformance: randomized fault schedules vs a fault-free
//! serial replica (DESIGN.md §11).
//!
//! Two independent full replicas of the kvpage + window state machine
//! run the same random op sequence — one uploads through the
//! double-buffered [`TransferPipeline`] while a seeded [`FaultPlan`]
//! injects worker panics, device-buffer loss, transfer stalls,
//! drained staging (the pool-dry admission behaviour) and failed
//! executes into it; the other runs the plain serial dirty-range path
//! with no faults at all. At every execute boundary the pipeline's
//! FRONT device contents and the serial device contents must both be
//! element-identical to their pools — faults may only cost
//! throughput, never a byte.
//!
//! On top of byte-identity the suite locks the recovery ladder
//! (demote on fault, re-promote to pipelined staging after the
//! backoff-bounded clean-step quota), the fence watchdog (a stalled
//! worker costs a bounded wait, not a hang), invariant I10 (all
//! cumulative fault/transfer counters are monotone under chaos), the
//! allocator audit I1–I4 after every injected fault, and that
//! zero-fault runs report zero demotions/retries.
//!
//! Corruption-bearing schedules (`FaultPlan::seeded_with_corrupt`,
//! the `cseed:` spec form) extend the storm with silent KV damage at
//! the three §14 stations — host pool page, staged snapshot, live
//! device window. The harness runs the engine-shaped integrity
//! protocol against them: a checksum scrub over the live pages before
//! every gather (repairing misses byte-for-byte from the fault-free
//! replica, the stand-in for quarantine + span re-prefill), a
//! device audit of the FRONT pair at the execute boundary (repairing
//! via `resync_front`), and the pipeline's own stamp check at the
//! staged-snapshot apply boundary. The same execute-boundary byte
//! compare then proves repair converged: corruption, like every
//! other fault, may cost throughput but never a byte. Invariant I12
//! (monotone integrity counters) rides the same per-step snapshot
//! that checks I10.
//!
//! `PF_FAULT_SEED=S` narrows the schedule sweep to one seed (the CI
//! chaos matrix); `PF_COPY_ENGINE=shared` stages through a shared
//! multiplexed engine; `PF_COPY_THREADS=N` shards the gather.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use paged_flex::engine::pipeline::TransferPipeline;
use paged_flex::engine::DegradeLevel;
use paged_flex::kvpage::{
    AllocError, GrowthPolicy, HostPool, PageAllocator, PageManager,
    PoolGeometry, ResidentWindow,
};
use paged_flex::runtime::{CopyEngine, CorruptTarget, DeviceWindow,
                          FaultInjector, FaultKind, FaultPlan};
use paged_flex::trace::Rng;

const N_PAGES: u32 = 48;
const PAGE_SIZE: usize = 8;
const BYTES_PER_TOKEN: u64 = 16;
const MAX_BLOCKS: usize = 12;
const GEO: PoolGeometry = PoolGeometry {
    n_layers: 2,
    n_pages: N_PAGES as usize,
    page_size: PAGE_SIZE,
    n_kv_heads: 2,
    d_head: 4,
};
const BATCH_CAP: usize = 4;
const WINDOW_PAGES: usize = BATCH_CAP * MAX_BLOCKS;

/// `PF_FAULT_SEED=S` → run just that schedule (the CI chaos matrix);
/// unset → sweep the defaults.
fn fault_seeds(defaults: &[u64]) -> Vec<u64> {
    match std::env::var("PF_FAULT_SEED")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
    {
        Some(s) => vec![s],
        None => defaults.to_vec(),
    }
}

fn env_copy_threads(default: usize) -> usize {
    std::env::var("PF_COPY_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

fn shared_engine() -> bool {
    std::env::var("PF_COPY_ENGINE").as_deref() == Ok("shared")
}

/// One full replica of the host-side decode state.
struct PathState {
    mgr: PageManager,
    k: HostPool,
    v: HostPool,
    win: ResidentWindow,
}

impl PathState {
    fn new(policy: GrowthPolicy) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        PathState {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            k: HostPool::zeros(GEO),
            v: HostPool::zeros(GEO),
            win: ResidentWindow::new(GEO),
        }
    }

    fn write_tokens(&mut self, id: u64, start: usize, n: usize,
                    counter: &mut f32) {
        let pages = self.mgr.table(id).unwrap().pages().to_vec();
        for pos in start..start + n {
            let (page, off) = (pages[pos / PAGE_SIZE], pos % PAGE_SIZE);
            for layer in 0..GEO.n_layers {
                *counter += 1.0;
                self.k.token_row_mut(layer, page, off).fill(*counter);
                self.v.token_row_mut(layer, page, off).fill(-*counter);
            }
        }
    }

    /// Allocator audit I1–I4 (DESIGN.md §7), run after every injected
    /// fault: chaos must never corrupt page accounting.
    fn check_audit(&self, live: &[u64], ctx: &str, path: &str) {
        let alloc = self.mgr.allocator();
        let mut held: HashMap<u32, u32> = HashMap::new();
        for &id in live {
            let t = self.mgr.table(id).unwrap();
            assert!(t.len_tokens() <= t.capacity_tokens(),
                    "{ctx}: {path} I3 violated for seq {id}");
            for &p in t.pages() {
                *held.entry(p).or_insert(0) += 1;
            }
        }
        for (&p, &n) in &held {
            assert!(alloc.refcount(p) >= n,
                    "{ctx}: {path} I2 page {p}: {n} holders > rc {}",
                    alloc.refcount(p));
        }
        // cached prefix pages are physically held by the index even
        // with no table owner (DESIGN.md §15)
        let mut physical = held.len();
        for p in self.mgr.cached_pages() {
            assert!(alloc.refcount(p) >= 1,
                    "{ctx}: {path} cached page {p} is dead");
            if !held.contains_key(&p) {
                physical += 1;
            }
        }
        assert_eq!(alloc.free_pages() + physical, N_PAGES as usize,
                   "{ctx}: {path} I1 conservation");
        let page_bytes = PAGE_SIZE as u64 * BYTES_PER_TOKEN;
        assert_eq!(alloc.audit().reserved_bytes(),
                   physical as u64 * page_bytes,
                   "{ctx}: {path} I4 reserved-bytes accounting");
    }
}

fn pick<'a>(rng: &mut Rng, xs: &'a [u64]) -> Option<&'a u64> {
    if xs.is_empty() {
        None
    } else {
        Some(&xs[rng.below(xs.len() as u64) as usize])
    }
}

struct ChaosHarness {
    /// Replica uploading through the (fault-injected) pipeline.
    p: PathState,
    pipe: TransferPipeline,
    /// Keeps the shared engine's owner alive for the run.
    _engine: Option<CopyEngine>,
    /// Fault-free serial replica (the reference stream).
    s: PathState,
    s_kdev: DeviceWindow,
    s_vdev: DeviceWindow,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
    counter_p: f32,
    counter_s: f32,
    /// Deterministic per-event salt for corruption injection (a
    /// dedicated counter so faults never perturb the shared op rng —
    /// both replicas must keep drawing the same op sequence).
    corrupt_salt: u64,
    /// Host/device corruptions that actually landed (a scheduled
    /// event is a no-op when no live page qualifies).
    host_corrupts: u64,
    device_corrupts: u64,
    /// Engine-shaped integrity ledger (invariant I12): all monotone.
    pages_corrupted: u64,
    pages_scrubbed: u64,
    pages_repaired: u64,
    device_resyncs: u64,
}

impl ChaosHarness {
    fn new(seed: u64, policy: GrowthPolicy, copy_threads: usize)
           -> Self {
        let mut p = PathState::new(policy);
        p.win.set_copy_threads(copy_threads);
        let (pipe, engine) = if shared_engine() {
            let e = CopyEngine::new(1);
            (TransferPipeline::sim_shared(&e, true), Some(e))
        } else {
            (TransferPipeline::sim(true), None)
        };
        ChaosHarness {
            p,
            pipe,
            _engine: engine,
            s: PathState::new(policy),
            s_kdev: DeviceWindow::sim(),
            s_vdev: DeviceWindow::sim(),
            live: vec![],
            next_id: 1,
            rng: Rng::seeded(seed),
            counter_p: 0.0,
            counter_s: 0.0,
            corrupt_salt: 0,
            host_corrupts: 0,
            device_corrupts: 0,
            pages_corrupted: 0,
            pages_scrubbed: 0,
            pages_repaired: 0,
            device_resyncs: 0,
        }
    }

    /// Map one scheduled fault onto the pipelined replica, exactly as
    /// `engine::paged` maps it (the serial replica never faults).
    fn apply_fault(&mut self, kind: FaultKind) {
        match kind {
            FaultKind::WorkerPanic => {
                self.pipe.poison_stream_for_test();
            }
            FaultKind::Stall => {
                // well under the fence watchdog set by the tests:
                // latency, not a timeout
                self.pipe.inject_stall(10_000_000);
            }
            FaultKind::BufferLoss => {
                self.p.win.invalidate();
                self.pipe.invalidate();
            }
            FaultKind::ExecFail => {
                self.p.win.invalidate();
                self.pipe.note_execute_failure();
            }
            FaultKind::AllocFail => {
                // the engine's pool-dry admission drains staging
                self.pipe.drain();
            }
            FaultKind::Corrupt(target) => self.apply_corrupt(target),
        }
    }

    /// Silent KV damage at one of the three §14 stations, exactly as
    /// `engine::paged` injects it. Only the pipelined replica is
    /// hit; the scrub/audit passes inside `decode_step_op` must
    /// repair it before the execute-boundary byte compare.
    fn apply_corrupt(&mut self, target: CorruptTarget) {
        self.corrupt_salt += 1;
        let salt = self.corrupt_salt;
        match target {
            CorruptTarget::HostPage => {
                if self.live.is_empty() {
                    return;
                }
                let id = self.live[salt as usize % self.live.len()];
                let pages =
                    self.p.mgr.table(id).unwrap().pages().to_vec();
                // completed pages only, as the engine injects it:
                // the tail page's next token write reseals it, so
                // tail bytes belong to the write path, not the scrub
                if pages.len() < 2 {
                    return;
                }
                let page = pages[salt as usize % (pages.len() - 1)];
                if salt & 1 == 0 {
                    self.p.k.corrupt_page_silently(page, salt);
                } else {
                    self.p.v.corrupt_page_silently(page, salt);
                }
                self.host_corrupts += 1;
            }
            CorruptTarget::StagedSnapshot => {
                // one-shot: the pipeline's own stamp check discards
                // the bent snapshot at the apply boundary
                self.pipe.corrupt_next_snapshot_for_test();
            }
            CorruptTarget::DeviceWindow => {
                if self.pipe.corrupt_front_for_test(salt) {
                    self.device_corrupts += 1;
                }
            }
        }
    }

    /// Engine-shaped host scrub at correctness-mode budget (every
    /// live page, every decode step): verify both pools against
    /// their write-time stamps before the gather can copy damage
    /// into the window. A miss is repaired byte-for-byte from the
    /// fault-free replica — the harness's stand-in for the engine's
    /// quarantine + span-re-prefill rung (the replicas must keep
    /// identical page numbering, which a real re-prefill through the
    /// allocator would break).
    fn scrub_hosts(&mut self) {
        let mut pages: Vec<u32> = vec![];
        for &id in &self.live {
            pages.extend_from_slice(
                self.p.mgr.table(id).unwrap().pages());
        }
        pages.sort_unstable();
        pages.dedup();
        for pg in pages {
            self.scrub_one(pg);
        }
    }

    /// Verify one page in both pools; repair misses from the
    /// reference replica and restamp.
    fn scrub_one(&mut self, pg: u32) {
        self.pages_scrubbed += 2;
        let k_ok = self.p.k.verify_page(pg);
        let v_ok = self.p.v.verify_page(pg);
        if !k_ok {
            self.pages_corrupted += 1;
            let flat = self.s.k.extract_page(pg);
            self.p.k.repair_page(pg, &flat);
            self.pages_repaired += 1;
        }
        if !v_ok {
            self.pages_corrupted += 1;
            let flat = self.s.v.extract_page(pg);
            self.p.v.repair_page(pg, &flat);
            self.pages_repaired += 1;
        }
    }

    /// Execute-boundary device audit (DESIGN.md §14): compare the
    /// FRONT pair against the live window for this step's mapped
    /// pages; any divergence re-uploads the whole window from the
    /// intact host copy (`resync_front`) before anything reads it.
    fn audit_device(&mut self, mapped: &[(u64, Vec<u32>)]) {
        let pe = GEO.page_elems();
        let mut bad = 0u64;
        let mut audited = 0u64;
        {
            let fk = match self.pipe.front().k.contents() {
                Some(c) => c,
                None => return,
            };
            let fv = match self.pipe.front().v.contents() {
                Some(c) => c,
                None => return,
            };
            for (_, pages) in mapped {
                for &pg in pages {
                    let Some(slot) = self.p.win.slot(pg) else {
                        continue;
                    };
                    audited += 1;
                    for layer in 0..GEO.n_layers {
                        let off = (layer * WINDOW_PAGES
                                   + slot as usize) * pe;
                        if fk[off..off + pe]
                            != *self.p.win.k_page_slice(layer, slot)
                            || fv[off..off + pe]
                                != *self.p.win.v_page_slice(layer,
                                                            slot)
                        {
                            bad += 1;
                            break;
                        }
                    }
                }
            }
        }
        self.pages_scrubbed += audited;
        if bad > 0 {
            self.pages_corrupted += bad;
            self.pipe.resync_front(&self.p.win);
            self.pages_repaired += bad;
            self.device_resyncs += 1;
        }
    }

    /// Cache surrender (LRU reclaim when the free list runs dry)
    /// kills pages without a FREE; both replicas evolve identically,
    /// so they surrender the same pages. Drop their window slots
    /// exactly like the free dead-list (DESIGN.md §15).
    fn drain_cache_evictions(&mut self) {
        for page in self.p.mgr.take_cache_evicted() {
            self.p.win.forget(page);
        }
        for page in self.s.mgr.take_cache_evicted() {
            self.s.win.forget(page);
        }
    }

    fn reserve_op(&mut self) {
        let id = self.next_id;
        let len = 1 + self.rng.below(60) as usize;
        let prompt: Vec<u32> =
            (0..len).map(|_| self.rng.below(512) as u32).collect();
        let a = self.p.mgr.reserve(id, &prompt);
        let b = self.s.mgr.reserve(id, &prompt);
        match (a, b) {
            (Ok(oa), Ok(ob)) => {
                assert_eq!(oa.cached_tokens, ob.cached_tokens,
                           "replicas diverged on admission");
                self.next_id += 1;
                self.live.push(id);
                let fresh = prompt.len() - oa.cached_tokens;
                self.p.write_tokens(id, oa.cached_tokens, fresh,
                                    &mut self.counter_p);
                self.s.write_tokens(id, ob.cached_tokens, fresh,
                                    &mut self.counter_s);
                self.p.mgr.note_assigned(id, fresh).unwrap();
                self.s.mgr.note_assigned(id, fresh).unwrap();
                if self.rng.below(2) == 0 {
                    self.p.mgr.register_prefix(id, &prompt).unwrap();
                    self.s.mgr.register_prefix(id, &prompt).unwrap();
                }
                // the engine reseals at its prefill flush boundary;
                // this op writes outside a decode step, so restamp
                // here — injected corruption must always land on a
                // sealed page (the scrub's detection domain, §14)
                self.p.k.seal_stale();
                self.p.v.seal_stale();
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on reserve outcome"),
        }
        self.drain_cache_evictions();
    }

    fn append_op(&mut self) {
        let Some(&id) = pick(&mut self.rng, &self.live) else { return };
        let extra = 1 + self.rng.below(10) as usize;
        let a = self.p.mgr.prepare_append(id, extra);
        let b = self.s.mgr.prepare_append(id, extra);
        match (a, b) {
            (Ok(pa), Ok(pb)) => {
                if let Some((src, dst)) = pa.cow_copy {
                    self.p.k.copy_page(src, dst);
                    self.p.v.copy_page(src, dst);
                }
                if let Some((src, dst)) = pb.cow_copy {
                    self.s.k.copy_page(src, dst);
                    self.s.v.copy_page(src, dst);
                }
                let len = self.p.mgr.seq_len(id).unwrap();
                self.p.write_tokens(id, len, extra,
                                    &mut self.counter_p);
                self.s.write_tokens(id, len, extra,
                                    &mut self.counter_s);
                self.p.mgr.note_assigned(id, extra).unwrap();
                self.s.mgr.note_assigned(id, extra).unwrap();
                // restamp boundary, as in reserve_op (§14)
                self.p.k.seal_stale();
                self.p.v.seal_stale();
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on append outcome"),
        }
        self.drain_cache_evictions();
    }

    fn free_op(&mut self, preempt: bool) {
        if self.live.is_empty() {
            return;
        }
        let i = self.rng.below(self.live.len() as u64) as usize;
        let id = self.live.swap_remove(i);
        // verify the retiring span before its pages can recycle: a
        // reallocated page is only partially rewritten by its next
        // owner, so damage parked beyond the new sequence's tokens
        // would otherwise outlive the checksum (the engine gets away
        // without this pass because attention masks beyond-length
        // rows; the harness's full-page byte compare does not)
        let retiring =
            self.p.mgr.table(id).unwrap().pages().to_vec();
        for pg in retiring {
            self.scrub_one(pg);
        }
        for page in self.p.mgr.free(id).unwrap() {
            self.p.win.forget(page);
        }
        for page in self.s.mgr.free(id).unwrap() {
            self.s.win.forget(page);
        }
        if preempt {
            self.p.win.invalidate();
            self.s.win.invalidate();
            self.pipe.drain();
        }
    }

    /// One engine-shaped decode step over a random batch; verifies the
    /// execute-boundary equivalence inside.
    fn decode_step_op(&mut self, ctx: &str) {
        // §14 scrub pass first: host damage must be repaired before
        // this step's gather (or a CoW copy below) can propagate it
        self.scrub_hosts();
        let mut batch: Vec<u64> = vec![];
        let want = 1 + self.rng.below(BATCH_CAP as u64) as usize;
        for _ in 0..want {
            if let Some(&id) = pick(&mut self.rng, &self.live) {
                if !batch.contains(&id) {
                    batch.push(id);
                }
            }
        }
        batch.retain(|&id| {
            let a = self.p.mgr.prepare_append(id, 1);
            let b = self.s.mgr.prepare_append(id, 1);
            match (a, b) {
                (Ok(pa), Ok(pb)) => {
                    if let Some((src, dst)) = pa.cow_copy {
                        self.p.k.copy_page(src, dst);
                        self.p.v.copy_page(src, dst);
                    }
                    if let Some((src, dst)) = pb.cow_copy {
                        self.s.k.copy_page(src, dst);
                        self.s.v.copy_page(src, dst);
                    }
                    true
                }
                (Err(_), Err(_)) => false,
                _ => panic!("{ctx}: replicas diverged on append"),
            }
        });
        self.drain_cache_evictions();
        if batch.is_empty() {
            return;
        }

        // pipelined replica: the engine's three stage boundaries
        self.pipe.begin_step(&mut self.p.win);
        self.p.win.begin_step(WINDOW_PAGES);
        let mut mapped: Vec<(u64, Vec<u32>)> = vec![];
        for &id in &batch {
            let len = self.p.mgr.seq_len(id).unwrap();
            let pages = self
                .p
                .mgr
                .table(id)
                .unwrap()
                .blocks_covering(len + 1)
                .to_vec();
            for &pg in &pages {
                self.p
                    .win
                    .map_page(&mut self.p.k, &mut self.p.v, pg)
                    .expect("pipeline window slots exhausted");
            }
            mapped.push((id, pages));
        }
        self.p.win.flush_pending(&self.p.k, &self.p.v);
        self.pipe.pre_execute(&mut self.p.win);
        // §14 device audit at the execute boundary: repair FRONT
        // damage before the byte compare (and the logits) read it
        self.audit_device(&mapped);

        // serial fault-free replica
        self.s.win.begin_step(WINDOW_PAGES);
        for (_, pages) in &mapped {
            for &pg in pages {
                self.s
                    .win
                    .map_page(&mut self.s.k, &mut self.s.v, pg)
                    .expect("serial window slots exhausted");
            }
        }
        let (plan, through) = self.s.win.plan_for(
            self.s_kdev.epoch().min(self.s_vdev.epoch()),
            false,
        );
        self.s_kdev.apply_at(self.s.win.k_window(), &plan, through);
        self.s_vdev.apply_at(self.s.win.v_window(), &plan, through);

        self.verify(ctx, &mapped);
        self.pipe.note_execute(1_000_000);

        for &id in &batch {
            let len = self.p.mgr.seq_len(id).unwrap();
            for (st, counter) in [
                (&mut self.p, &mut self.counter_p),
                (&mut self.s, &mut self.counter_s),
            ] {
                let pages = st.mgr.table(id).unwrap().pages().to_vec();
                let (page, off) =
                    (pages[len / PAGE_SIZE], len % PAGE_SIZE);
                for layer in 0..GEO.n_layers {
                    *counter += 1.0;
                    st.k.token_row_mut(layer, page, off).fill(*counter);
                    st.v.token_row_mut(layer, page, off)
                        .fill(-*counter);
                    st.win.write_row(&mut st.k, &mut st.v, layer, page,
                                     off);
                }
                st.mgr.note_assigned(id, 1).unwrap();
            }
        }
        self.p.win.flush_rows(&self.p.k, &self.p.v);
        self.s.win.flush_rows(&self.s.k, &self.s.v);
    }

    /// For every mapped page the pipeline's FRONT device pair and the
    /// serial device pair are element-identical to their pools (and
    /// the pools are identical by construction): chaos never changes
    /// a served byte.
    fn verify(&self, ctx: &str, mapped: &[(u64, Vec<u32>)]) {
        let pe = GEO.page_elems();
        let fk = self.pipe.front().k.contents()
            .expect("pipeline front K resident after pre_execute");
        let fv = self.pipe.front().v.contents()
            .expect("pipeline front V resident after pre_execute");
        let sk = self.s_kdev.contents()
            .expect("serial K resident after apply");
        let sv = self.s_vdev.contents()
            .expect("serial V resident after apply");
        for (id, pages) in mapped {
            for &p in pages {
                let ps = self.p.win.slot(p).unwrap() as usize;
                let ss = self.s.win.slot(p).unwrap() as usize;
                for layer in 0..GEO.n_layers {
                    let src = GEO.offset(layer, p, 0);
                    let kp = &self.p.k.as_slice()[src..src + pe];
                    let vp = &self.p.v.as_slice()[src..src + pe];
                    let poff = (layer * WINDOW_PAGES + ps) * pe;
                    let soff = (layer * WINDOW_PAGES + ss) * pe;
                    assert_eq!(&fk[poff..poff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: faulted FRONT device stale");
                    assert_eq!(&fv[poff..poff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: faulted FRONT device stale");
                    assert_eq!(&sk[soff..soff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: serial reference diverged");
                    assert_eq!(&sv[soff..soff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: serial reference diverged");
                }
            }
        }
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(10) {
            0..=2 => self.reserve_op(),
            3 => self.append_op(),
            4 => self.free_op(false),
            5 => self.free_op(true),
            _ => self.decode_step_op(ctx),
        }
    }

    fn check_audit(&self, ctx: &str) {
        self.p.check_audit(&self.live, ctx, "faulted");
        self.s.check_audit(&self.live, ctx, "serial");
    }
}

/// I10 + I12 snapshot: every cumulative fault/transfer counter,
/// retired upload bytes, and the integrity ledger. All must be
/// monotone non-decreasing under chaos.
#[derive(Clone, Copy, Default)]
struct Monotone {
    steps: u64,
    staged_uploads: u64,
    staged_bytes: u64,
    poisons: u64,
    faults: u64,
    demotes: u64,
    repromotes: u64,
    retries: u64,
    fence_timeouts: u64,
    bytes_uploaded: u64,
    staged_corrupt: u64,
    pages_corrupted: u64,
    pages_scrubbed: u64,
    pages_repaired: u64,
}

impl Monotone {
    fn snap(h: &ChaosHarness) -> Self {
        let s = h.pipe.stats();
        Monotone {
            steps: s.steps,
            staged_uploads: s.staged_uploads,
            staged_bytes: s.staged_bytes,
            poisons: s.poisons,
            faults: s.faults,
            demotes: s.demotes,
            repromotes: s.repromotes,
            retries: s.retries,
            fence_timeouts: s.fence_timeouts,
            bytes_uploaded: h.pipe.upload_stats().bytes_uploaded,
            staged_corrupt: s.staged_corrupt,
            pages_corrupted: h.pages_corrupted,
            pages_scrubbed: h.pages_scrubbed,
            pages_repaired: h.pages_repaired,
        }
    }

    fn assert_ge(&self, prev: &Monotone, ctx: &str) {
        for (inv, name, now, was) in [
            ("I10", "steps", self.steps, prev.steps),
            ("I10", "staged_uploads", self.staged_uploads,
             prev.staged_uploads),
            ("I10", "staged_bytes", self.staged_bytes,
             prev.staged_bytes),
            ("I10", "poisons", self.poisons, prev.poisons),
            ("I10", "faults", self.faults, prev.faults),
            ("I10", "demotes", self.demotes, prev.demotes),
            ("I10", "repromotes", self.repromotes, prev.repromotes),
            ("I10", "retries", self.retries, prev.retries),
            ("I10", "fence_timeouts", self.fence_timeouts,
             prev.fence_timeouts),
            ("I10", "bytes_uploaded", self.bytes_uploaded,
             prev.bytes_uploaded),
            ("I12", "staged_corrupt", self.staged_corrupt,
             prev.staged_corrupt),
            ("I12", "pages_corrupted", self.pages_corrupted,
             prev.pages_corrupted),
            ("I12", "pages_scrubbed", self.pages_scrubbed,
             prev.pages_scrubbed),
            ("I12", "pages_repaired", self.pages_repaired,
             prev.pages_repaired),
        ] {
            assert!(now >= was,
                    "{ctx}: {inv} counter {name} went backwards \
                     ({was} -> {now})");
        }
    }
}

/// Drive one seeded chaos schedule to completion. Returns the
/// harness for end-state assertions.
fn chaos_run(seed: u64, steps: usize, fault_count: usize)
             -> ChaosHarness {
    let plan = FaultPlan::seeded(
        seed, (steps as u64).saturating_sub(steps as u64 / 4),
        fault_count);
    chaos_run_plan(plan, seed, steps)
}

/// Drive an explicit plan (legacy or corruption-bearing) through the
/// harness; `seed` picks the op-sequence rng and growth policy.
fn chaos_run_plan(plan: FaultPlan, seed: u64, steps: usize)
                  -> ChaosHarness {
    let policy = if seed % 2 == 0 {
        GrowthPolicy::Exact
    } else {
        GrowthPolicy::PowerOfTwo
    };
    let mut inj = FaultInjector::new(plan);
    let mut h = ChaosHarness::new(31_000 + seed, policy,
                                  env_copy_threads(1));
    // generous next to a 10 ms injected stall, tiny next to a hang
    h.pipe.set_fence_timeout(Duration::from_millis(500));
    let mut prev = Monotone::snap(&h);
    for step in 0..steps {
        let ctx = format!("chaos seed {seed} step {step} ({policy:?})");
        let fired = inj.begin_step();
        for kind in &fired {
            h.apply_fault(*kind);
        }
        h.step(&ctx);
        if !fired.is_empty() {
            // satellite: allocator audit after every injected fault
            h.check_audit(&ctx);
        }
        let now = Monotone::snap(&h);
        now.assert_ge(&prev, &ctx);
        prev = now;
    }
    assert!(inj.injected() >= 1,
            "seed {seed}: schedule never fired (horizon too small?)");
    while !h.live.is_empty() {
        h.free_op(false);
    }
    for page in h.p.mgr.flush_prefix_cache() {
        h.p.win.forget(page);
    }
    for page in h.s.mgr.flush_prefix_cache() {
        h.s.win.forget(page);
    }
    assert_eq!(h.p.mgr.allocator().free_pages(), N_PAGES as usize,
               "seed {seed}: faulted replica leaked pages");
    assert_eq!(h.s.mgr.allocator().free_pages(), N_PAGES as usize,
               "seed {seed}: serial replica leaked pages");
    h
}

#[test]
fn seeded_fault_schedules_keep_streams_byte_identical() {
    for seed in fault_seeds(&[3, 17, 29]) {
        let h = chaos_run(seed, 260, 10);
        let ps = h.pipe.stats();
        assert!(ps.staged_uploads > 0,
                "seed {seed}: pipeline never staged ({ps:?})");
    }
}

#[test]
fn corruption_schedules_converge_byte_identical_after_repair() {
    // `cseed:`-form plans add the three §14 corruption stations to
    // the storm; the scrub/audit/stamp-check ladder must repair
    // every hit before the execute-boundary byte compare inside
    // `decode_step_op` — which is the real lock here: a missed or
    // botched repair fails the run as a byte divergence.
    let mut exercised = 0u64;
    for seed in fault_seeds(&[41, 57]) {
        let steps = 260usize;
        let plan = FaultPlan::seeded_with_corrupt(
            seed, (steps as u64).saturating_sub(steps as u64 / 4),
            14);
        let h = chaos_run_plan(plan, seed, steps);
        let ps = h.pipe.stats();
        assert!(ps.staged_uploads > 0,
                "seed {seed}: pipeline never staged ({ps:?})");
        assert_eq!(h.pages_corrupted, h.pages_repaired,
                   "seed {seed}: detected damage left unrepaired");
        assert!(h.pages_scrubbed > 0,
                "seed {seed}: scrub detection pass never ran");
        exercised += h.host_corrupts + h.device_corrupts
            + ps.staged_corrupt;
    }
    assert!(exercised >= 1,
            "corruption sweep never landed a single hit — the \
             schedules exercise nothing");
}

#[test]
fn i12_corruption_storm_counters_stay_monotone() {
    // Denser corruption-bearing schedule; the per-step Monotone
    // snapshot inside `chaos_run_plan` checks I10 + I12 throughout.
    for seed in fault_seeds(&[303]) {
        let plan = FaultPlan::seeded_with_corrupt(seed, 150, 30);
        let h = chaos_run_plan(plan, seed, 200);
        assert_eq!(h.pages_corrupted, h.pages_repaired,
                   "seed {seed}: corrupted/repaired diverged at end");
        assert!(h.pages_scrubbed >= h.pages_corrupted,
                "seed {seed}: more detections than verifications");
    }
}

#[test]
fn fault_storm_demotes_then_repromotes_to_pipelined() {
    // Deterministic storm: three ladder faults in a row walk the pool
    // to Rebuild; the backoff quota (4 -> 8 -> 16, capped) then
    // requires at most 16 clean steps per rung to climb home.
    let mut h = ChaosHarness::new(55, GrowthPolicy::Exact, 1);
    while h.live.is_empty() {
        h.reserve_op();
    }
    for i in 0..4 {
        h.decode_step_op(&format!("storm warmup {i}"));
    }
    h.apply_fault(FaultKind::WorkerPanic);
    h.decode_step_op("storm a"); // settle sees the poisoned fence
    h.apply_fault(FaultKind::ExecFail);
    h.apply_fault(FaultKind::ExecFail);
    assert!(h.pipe.degrade_level() > DegradeLevel::Pipelined,
            "storm must demote, at {:?}", h.pipe.degrade_level());
    let mut recovered_at = None;
    for i in 0..80 {
        h.decode_step_op(&format!("recovery {i}"));
        if h.pipe.degrade_level() == DegradeLevel::Pipelined {
            recovered_at = Some(i);
            break;
        }
    }
    assert!(recovered_at.is_some(),
            "pool never re-promoted to pipelined within 80 clean \
             steps (level {:?}, stats {:?})",
            h.pipe.degrade_level(), h.pipe.stats());
    // the fresh lane must actually stage again after recovery
    let staged_before = h.pipe.stats().staged_uploads;
    for i in 0..6 {
        h.decode_step_op(&format!("post-recovery {i}"));
    }
    assert!(h.pipe.stats().staged_uploads > staged_before,
            "re-promoted pool never staged again ({:?})",
            h.pipe.stats());
    assert!(h.pipe.stats().repromotes >= 1, "{:?}", h.pipe.stats());
    assert!(h.pipe.stats().demotes >= 3, "{:?}", h.pipe.stats());
}

#[test]
fn stalled_transfer_times_out_instead_of_hanging() {
    let mut h = ChaosHarness::new(99, GrowthPolicy::Exact, 1);
    h.pipe.set_fence_timeout(Duration::from_millis(25));
    while h.live.is_empty() {
        h.reserve_op();
    }
    for i in 0..4 {
        h.decode_step_op(&format!("stall warmup {i}"));
    }
    // park the worker far past the watchdog; the next settle must cut
    // the stalled transfer loose instead of riding it out
    h.pipe.inject_stall(400_000_000);
    let t = Instant::now();
    for i in 0..6 {
        h.decode_step_op(&format!("stall step {i}"));
    }
    assert!(t.elapsed() < Duration::from_millis(350),
            "watchdog failed to bound a stalled transfer \
             ({:?} elapsed, stats {:?})", t.elapsed(),
            h.pipe.stats());
    assert!(h.pipe.stats().fence_timeouts >= 1,
            "stall never tripped the watchdog ({:?})",
            h.pipe.stats());
    assert!(h.pipe.degrade_level() > DegradeLevel::Pipelined
                || h.pipe.stats().repromotes >= 1,
            "timeout must demote (or already have recovered)");
}

#[test]
fn zero_fault_run_reports_zero_demotes_and_retries() {
    let mut h = ChaosHarness::new(7, GrowthPolicy::Exact,
                                  env_copy_threads(1));
    for step in 0..200 {
        h.step(&format!("clean step {step}"));
    }
    let ps = h.pipe.stats();
    assert!(ps.staged_uploads > 0, "never staged ({ps:?})");
    assert_eq!(ps.faults, 0, "clean run reported faults ({ps:?})");
    assert_eq!(ps.demotes, 0, "clean run reported demotes ({ps:?})");
    assert_eq!(ps.retries, 0, "clean run reported retries ({ps:?})");
    assert_eq!(ps.fence_timeouts, 0,
               "clean run tripped the watchdog ({ps:?})");
    assert_eq!(ps.poisons, 0, "clean run reported poisons ({ps:?})");
    assert_eq!(h.pipe.degrade_level(), DegradeLevel::Pipelined);
    // §14: scrubbing runs on clean steps too, but the repair path is
    // corruption-only — a zero-fault run must never touch it
    assert!(h.pages_scrubbed > 0, "scrub pass never ran");
    assert_eq!(h.pages_corrupted, 0,
               "clean run detected phantom corruption");
    assert_eq!(h.pages_repaired, 0, "clean run repaired something");
    assert_eq!(h.device_resyncs, 0, "clean run resynced the front");
    assert_eq!(ps.staged_corrupt, 0,
               "clean run discarded a snapshot ({ps:?})");
}

#[test]
fn corrupt_shared_prefix_page_unshares_all_owners() {
    // §14 meets §15: silent damage lands on a page the prefix cache
    // shares across several owners. Quarantine must atomically
    // un-share — every owner is discoverable for the coordinator's
    // requeue, the radix entry and its descendants leave the index,
    // no later admission re-aliases the damaged bytes, the sharing
    // counter stays monotone without moving, and the page retires
    // instead of recycling when its last owner dies.
    let alloc = Arc::new(PageAllocator::new(
        N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, GrowthPolicy::Exact));
    let mut mgr = PageManager::new(alloc, MAX_BLOCKS);
    let mut k = HostPool::zeros(GEO);
    let mut v = HostPool::zeros(GEO);
    let mut win = ResidentWindow::new(GEO);

    let prompt: Vec<u32> = (0..24).collect(); // exactly 3 pages
    mgr.reserve(1, &prompt).unwrap();
    mgr.note_assigned(1, prompt.len()).unwrap();
    assert_eq!(mgr.register_prefix(1, &prompt).unwrap(), 3);
    for seq in [2u64, 3] {
        let out = mgr.reserve(seq, &prompt).unwrap();
        assert_eq!(out.cached_tokens, 16, "seq {seq} aliased 2 pages");
        mgr.note_assigned(seq, prompt.len() - out.cached_tokens)
            .unwrap();
    }
    let shared = mgr.table(1).unwrap().pages()[0];
    win.begin_step(WINDOW_PAGES);
    win.map_page(&mut k, &mut v, shared).unwrap();
    assert!(win.resident_pages().contains(&shared));

    // the scrub detects damage on the shared page: quarantine
    assert_eq!(mgr.owners_of(shared), vec![1, 2, 3],
               "every owner must be discoverable for requeue");
    let shares_before = mgr.shared_pages_total();
    mgr.quarantine_page(shared);
    for page in mgr.take_cache_evicted() {
        win.forget(page);
    }

    // the index entry and its radix descendants are gone: the next
    // admission recomputes instead of aliasing damaged bytes
    let out = mgr.reserve(4, &prompt).unwrap();
    assert_eq!(out.cached_tokens, 0, "no re-alias after quarantine");
    assert!(!mgr.table(4).unwrap().pages().contains(&shared));
    mgr.note_assigned(4, prompt.len()).unwrap();
    assert_eq!(mgr.shared_pages_total(), shares_before,
               "quarantine must not serve new pages by aliasing");

    // owners drain (the coordinator's requeue frees their spans);
    // the damaged page retires instead of recycling
    for seq in [1u64, 2, 3, 4] {
        for page in mgr.free(seq).unwrap() {
            win.forget(page);
        }
    }
    for page in mgr.flush_prefix_cache() {
        win.forget(page);
    }
    mgr.take_cache_evicted();
    assert!(!win.resident_pages().contains(&shared),
            "window slot survived quarantine retirement");
    assert!(mgr.allocator().is_quarantined(shared));
    assert_eq!(mgr.allocator().free_pages(), N_PAGES as usize - 1,
               "damaged page must retire, not recycle");
}

#[test]
fn i10_heavy_schedules_counters_stay_monotone() {
    // Denser schedules than the byte-identity sweep: every kind fires
    // several times, including back-to-back events on one step.
    for seed in fault_seeds(&[101, 202]) {
        let h = chaos_run(seed, 200, 24);
        let ps = h.pipe.stats();
        assert!(ps.faults >= ps.demotes || ps.demotes == 0,
                "seed {seed}: more demotes than faults ({ps:?})");
    }
}
