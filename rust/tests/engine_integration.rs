//! Engine-level integration over real tiny artifacts (skip if absent).
//!
//! The centrepiece is cross-path numerical equivalence: the SAME prompt
//! greedily decoded through the paged, contiguous, and no-cache paths
//! must produce the SAME tokens — the Rust-level analog of the paper's
//! perplexity-equivalence claim (Sec. IV-B.3), now covering the page
//! manager, subpool gather/remap, scatter, and all three artifact
//! families at once.

use std::path::{Path, PathBuf};

use paged_flex::config::{AttentionMode, EngineConfig, SamplingConfig};
use paged_flex::engine::{Engine, Sampler};
use paged_flex::trace::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn cfg(mode: AttentionMode, dir: &Path) -> EngineConfig {
    let mut c = EngineConfig::default();
    c.model = "tiny".into();
    c.artifacts_dir = dir.to_path_buf();
    c.attention = mode;
    c.scheduler.prefill_chunk = 32;
    c
}

fn prompt(seed: u64, len: usize) -> Vec<u32> {
    let mut rng = Rng::seeded(seed);
    (0..len).map(|_| rng.below(512) as u32).collect()
}

fn greedy_generate(mode: AttentionMode, dir: &Path, p: &[u32],
                   n: usize) -> Vec<u32> {
    let mut eng = Engine::new(cfg(mode, dir)).unwrap();
    let mut s = Sampler::new(SamplingConfig::greedy());
    eng.generate(p, n, &mut s).unwrap()
}

#[test]
fn all_three_paths_generate_identical_tokens() {
    let Some(dir) = artifacts() else { return };
    let p = prompt(42, 30);
    let paged = greedy_generate(AttentionMode::Paged, &dir, &p, 12);
    let contig = greedy_generate(AttentionMode::Contiguous, &dir, &p, 12);
    let nocache = greedy_generate(AttentionMode::NoCache, &dir, &p, 12);
    assert_eq!(paged, contig,
               "paged vs contiguous diverged: the paper's numerical-\
                equivalence claim fails at the Rust level");
    assert_eq!(paged, nocache, "paged vs full-recompute diverged");
}

#[test]
fn chunked_prefill_equals_one_shot() {
    let Some(dir) = artifacts() else { return };
    let p = prompt(7, 50);
    // chunk 16 forces 4 chunks; chunk 64 does it in one
    let mut c1 = cfg(AttentionMode::Paged, &dir);
    c1.scheduler.prefill_chunk = 16;
    let mut c2 = cfg(AttentionMode::Paged, &dir);
    c2.scheduler.prefill_chunk = 64;
    let mut out = vec![];
    for c in [c1, c2] {
        let mut eng = Engine::new(c).unwrap();
        let mut s = Sampler::new(SamplingConfig::greedy());
        out.push(eng.generate(&p, 8, &mut s).unwrap());
    }
    assert_eq!(out[0], out[1], "chunked prefill changed the numbers");
}

#[test]
fn batched_decode_matches_single() {
    let Some(dir) = artifacts() else { return };
    let p1 = prompt(1, 20);
    let p2 = prompt(2, 33);

    // singles
    let s1 = greedy_generate(AttentionMode::Paged, &dir, &p1, 6);
    let s2 = greedy_generate(AttentionMode::Paged, &dir, &p2, 6);

    // batched through the same engine (batch bucket b=2)
    let mut eng = Engine::new(cfg(AttentionMode::Paged, &dir)).unwrap();
    let (a, b) = (eng.fresh_seq_id(), eng.fresh_seq_id());
    let pe = eng.paged.as_mut().unwrap();
    pe.admit(a, &p1).unwrap();
    pe.admit(b, &p2).unwrap();
    let mut logits = std::collections::HashMap::new();
    loop {
        let pending: Vec<_> = [a, b]
            .iter()
            .copied()
            .filter(|id| pe.seq(*id).unwrap().remaining_prefill() > 0)
            .collect();
        if pending.is_empty() {
            break;
        }
        for (id, done, row) in
            pe.prefill_chunk(&eng.rt, &pending, 64).unwrap()
        {
            if done {
                logits.insert(id, row);
            }
        }
    }
    let mut got1 = vec![];
    let mut got2 = vec![];
    for _ in 0..6 {
        let t1 = paged_flex::engine::argmax(&logits[&a]);
        let t2 = paged_flex::engine::argmax(&logits[&b]);
        got1.push(t1);
        got2.push(t2);
        for (id, row) in
            pe.decode_step(&eng.rt, &[a, b], &[t1, t2]).unwrap()
        {
            logits.insert(id, row);
        }
    }
    assert_eq!(got1, s1, "seq 1 diverged under batching");
    assert_eq!(got2, s2, "seq 2 diverged under batching");
}

#[test]
fn prefix_cache_reuse_preserves_output() {
    let Some(dir) = artifacts() else { return };
    let p = prompt(9, 32); // 4 full pages at page_size 8
    let mut eng = Engine::new(cfg(AttentionMode::Paged, &dir)).unwrap();
    let mut s = Sampler::new(SamplingConfig::greedy());
    let first = eng.generate(&p, 6, &mut s).unwrap();
    // second identical request: served from cached prefix pages
    let hits_before = eng.paged.as_ref().unwrap().mgr.prefix_cache_len();
    assert!(hits_before == 0,
            "pages were freed with the sequence, cache must be empty");
    let mut s = Sampler::new(SamplingConfig::greedy());
    let second = eng.generate(&p, 6, &mut s).unwrap();
    assert_eq!(first, second, "second request changed the output");
}

#[test]
fn preemption_recompute_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let p = prompt(5, 24);
    let mut eng = Engine::new(cfg(AttentionMode::Paged, &dir)).unwrap();
    let id = eng.fresh_seq_id();
    let pe = eng.paged.as_mut().unwrap();
    pe.admit(id, &p).unwrap();
    let out = pe.prefill_chunk(&eng.rt, &[id], 64).unwrap();
    assert!(out[0].1, "prefill finished");
    let free_after_admit = pe.mgr.allocator().free_pages();

    // preempt: pages return to the pool, tokens survive
    let tokens = pe.preempt(id).unwrap();
    assert_eq!(tokens, p);
    assert!(pe.mgr.allocator().free_pages() > free_after_admit);

    // re-admit + re-prefill gives the same logits (recompute semantics)
    let id2 = 999;
    pe.admit(id2, &tokens).unwrap();
    let out2 = pe.prefill_chunk(&eng.rt, &[id2], 64).unwrap();
    assert_eq!(out[0].2, out2[0].2, "recompute changed the logits");
}

#[test]
fn memory_audit_tracks_a_generation() {
    let Some(dir) = artifacts() else { return };
    let p = prompt(3, 20);
    let mut eng = Engine::new(cfg(AttentionMode::Paged, &dir)).unwrap();
    let mut s = Sampler::new(SamplingConfig::greedy());
    eng.generate(&p, 8, &mut s).unwrap();
    let audit = eng.paged.as_ref().unwrap().mgr.allocator().audit();
    assert_eq!(audit.reserved_bytes(), 0, "release leaked reservations");
    assert_eq!(audit.live_bytes(), 0);
    assert!(audit.peak_reserved_bytes() > 0);
    // 28 tokens at page 8 -> 4 pages -> peak >= 4 pages of KV bytes
    let kv_per_page = 8 * eng.rt.spec().kv_bytes_per_token as u64;
    assert!(audit.peak_reserved_bytes() >= 4 * kv_per_page);
}
