//! Cross-engine differential conformance (skip if artifacts absent).
//!
//! The pipeline PR's end-to-end guarantee: overlapping transfer with
//! compute changes NOTHING observable. Randomized mixed prefill/decode
//! traces (`trace::mixed_batch`) are served through four engine
//! configurations — paged with the transfer pipeline on, paged with
//! `--pipeline off`, contiguous, and nocache — and every request's
//! greedy token stream must be byte-identical across all of them. A
//! second set of tests drives preempt/resume and fork interleavings
//! through the paged engine directly (pipeline on AND off) against
//! uninterrupted references.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use paged_flex::config::{AttentionMode, CopyEngineCfg, EngineConfig,
                         SamplingConfig};
use paged_flex::coordinator::{Coordinator, Request};
use paged_flex::engine::{argmax, Engine, Sampler};
use paged_flex::trace::mixed_batch;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn cfg(mode: AttentionMode, dir: &Path, pipeline: bool) -> EngineConfig {
    let mut c = EngineConfig::default();
    c.model = "tiny".into();
    c.artifacts_dir = dir.to_path_buf();
    c.attention = mode;
    c.pipeline = pipeline;
    c.scheduler.prefill_chunk = 32;
    // the CI threaded-stress job sets PF_COPY_THREADS=4 so the whole
    // differential suite also runs with the sharded gather AND the
    // threaded ASSIGN scatter; token streams must stay byte-identical
    // at any shard width
    if let Some(n) = std::env::var("PF_COPY_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        c.copy_threads = n.max(1);
    }
    // the CI shared-engine stress job sets PF_COPY_ENGINE=shared so
    // every engine in the suite multiplexes its staged uploads
    // through the process-wide copy engine; streams must not change
    if std::env::var("PF_COPY_ENGINE").as_deref() == Ok("shared") {
        c.copy_engine = CopyEngineCfg::Shared;
    }
    c
}

/// Serve `reqs` to completion under `cfg`; id → generated tokens.
fn serve(cfg: EngineConfig, reqs: &[(u64, Vec<u32>, usize)])
         -> HashMap<u64, Vec<u32>> {
    let engine = Engine::new(cfg).unwrap();
    let mut coord = Coordinator::new(engine);
    for (id, prompt, max_new) in reqs {
        coord
            .submit(Request::greedy(*id, prompt.clone(), *max_new))
            .unwrap();
    }
    let fins = coord.run_to_completion().unwrap();
    fins.into_iter()
        .inspect(|f| assert!(f.error.is_none(),
                             "request {} errored: {:?}", f.id, f.error))
        .map(|f| (f.id, f.tokens))
        .collect()
}

#[test]
fn mixed_traces_identical_across_engines_and_pipeline_modes() {
    let Some(dir) = artifacts() else { return };
    for seed in [11u64, 23, 47] {
        // lengths on the {8, 16, ..., 48} grid, scaled to the tiny
        // model (the paper's 500..8000 grid shape, Sec. IV-A)
        let reqs: Vec<(u64, Vec<u32>, usize)> =
            mixed_batch(seed, 512, 5, 8, 48, 6)
                .into_iter()
                .map(|r| (r.id, r.prompt, r.max_new_tokens))
                .collect();

        let pipe_on =
            serve(cfg(AttentionMode::Paged, &dir, true), &reqs);
        let pipe_off =
            serve(cfg(AttentionMode::Paged, &dir, false), &reqs);
        let contig =
            serve(cfg(AttentionMode::Contiguous, &dir, true), &reqs);
        let nocache =
            serve(cfg(AttentionMode::NoCache, &dir, true), &reqs);

        for (id, _, _) in &reqs {
            assert_eq!(pipe_on[id], pipe_off[id],
                       "seed {seed} req {id}: pipeline changed the \
                        tokens");
            assert_eq!(pipe_on[id], contig[id],
                       "seed {seed} req {id}: paged vs contiguous \
                        diverged");
            assert_eq!(pipe_on[id], nocache[id],
                       "seed {seed} req {id}: paged vs full-recompute \
                        diverged");
        }
    }
}

/// Multi-model serving conformance: TWO paged engines run with
/// `copy_engine = shared` and are ticked interleaved, and each
/// engine's greedy streams must match its solo-engine run
/// token-for-token. (On the artifact path the pipeline rides the
/// accounting-only PJRT backing, which never stages — so this pins
/// the config plumbing and end-to-end conformance of the interleaved
/// two-engine run; the shared lanes themselves are contended and
/// byte-checked by the sim-backed `copy_stream_multiplex` suite and
/// `benches/multiplex_overlap.rs`.)
#[test]
fn two_engines_sharing_one_copy_engine_match_solo_streams() {
    let Some(dir) = artifacts() else { return };
    let shared = |seed_batch: u64| -> Vec<(u64, Vec<u32>, usize)> {
        mixed_batch(seed_batch, 512, 4, 8, 40, 6)
            .into_iter()
            .map(|r| (r.id, r.prompt, r.max_new_tokens))
            .collect()
    };
    let reqs_a = shared(71);
    let reqs_b = shared(72);
    let mut scfg = cfg(AttentionMode::Paged, &dir, true);
    scfg.copy_engine = CopyEngineCfg::Shared;

    // solo references (each also on the shared engine, run alone)
    let solo_a = serve(scfg.clone(), &reqs_a);
    let solo_b = serve(scfg.clone(), &reqs_b);

    // interleaved two-engine run: tick the coordinators alternately
    let mut c1 = Coordinator::new(Engine::new(scfg.clone()).unwrap());
    let mut c2 = Coordinator::new(Engine::new(scfg).unwrap());
    for (id, prompt, max_new) in &reqs_a {
        c1.submit(Request::greedy(*id, prompt.clone(), *max_new))
            .unwrap();
    }
    for (id, prompt, max_new) in &reqs_b {
        c2.submit(Request::greedy(*id, prompt.clone(), *max_new))
            .unwrap();
    }
    let mut fin_a = Vec::new();
    let mut fin_b = Vec::new();
    while !c1.idle() || !c2.idle() {
        let mut progressed = false;
        if !c1.idle() {
            progressed |= c1.tick().unwrap();
            fin_a.extend(c1.drain_finished());
        }
        if !c2.idle() {
            progressed |= c2.tick().unwrap();
            fin_b.extend(c2.drain_finished());
        }
        assert!(progressed, "interleaved schedulers stalled");
    }
    let got_a: HashMap<u64, Vec<u32>> = fin_a
        .into_iter()
        .inspect(|f| assert!(f.error.is_none(),
                             "engine A request {} errored: {:?}",
                             f.id, f.error))
        .map(|f| (f.id, f.tokens))
        .collect();
    let got_b: HashMap<u64, Vec<u32>> = fin_b
        .into_iter()
        .inspect(|f| assert!(f.error.is_none(),
                             "engine B request {} errored: {:?}",
                             f.id, f.error))
        .map(|f| (f.id, f.tokens))
        .collect();
    for (id, _, _) in &reqs_a {
        assert_eq!(got_a[id], solo_a[id],
                   "req {id}: engine A diverged from its solo run \
                    under the shared copy engine");
    }
    for (id, _, _) in &reqs_b {
        assert_eq!(got_b[id], solo_b[id],
                   "req {id}: engine B diverged from its solo run \
                    under the shared copy engine");
    }
}

fn prompt(seed: u64, len: usize) -> Vec<u32> {
    let mut rng = paged_flex::trace::Rng::seeded(seed);
    (0..len).map(|_| rng.below(512) as u32).collect()
}

/// Uninterrupted greedy reference through the paged path.
fn reference(dir: &Path, pipeline: bool, p: &[u32], n: usize)
             -> Vec<u32> {
    let mut eng =
        Engine::new(cfg(AttentionMode::Paged, dir, pipeline)).unwrap();
    let mut s = Sampler::new(SamplingConfig::greedy());
    eng.generate(p, n, &mut s).unwrap()
}

/// Preempt/resume interleaving: two sequences decode together; one is
/// preempted mid-stream (recompute-style: pages freed, tokens kept,
/// staged pipeline uploads drained), re-admitted, re-prefilled, and
/// decoded on — its final stream must equal the uninterrupted run.
fn preempt_resume_roundtrip(pipeline: bool) {
    let Some(dir) = artifacts() else { return };
    let p1 = prompt(61, 24);
    let p2 = prompt(62, 17);
    let ref1 = reference(&dir, pipeline, &p1, 8);
    let ref2 = reference(&dir, pipeline, &p2, 8);

    let mut eng =
        Engine::new(cfg(AttentionMode::Paged, &dir, pipeline)).unwrap();
    let (a, b) = (eng.fresh_seq_id(), eng.fresh_seq_id());
    let pe = eng.paged.as_mut().unwrap();
    pe.admit(a, &p1).unwrap();
    pe.admit(b, &p2).unwrap();
    let mut logits: HashMap<u64, Vec<f32>> = HashMap::new();
    for id in [a, b] {
        loop {
            let out = pe.prefill_chunk(&eng.rt, &[id], 32).unwrap();
            let (_, done, row) = out.into_iter().next().unwrap();
            if done {
                logits.insert(id, row);
                break;
            }
        }
    }
    let mut got: HashMap<u64, Vec<u32>> =
        [(a, vec![]), (b, vec![])].into();

    // 3 joint decode steps
    for _ in 0..3 {
        let (t1, t2) = (argmax(&logits[&a]), argmax(&logits[&b]));
        got.get_mut(&a).unwrap().push(t1);
        got.get_mut(&b).unwrap().push(t2);
        for (id, row) in
            pe.decode_step(&eng.rt, &[a, b], &[t1, t2]).unwrap()
        {
            logits.insert(id, row);
        }
    }

    // preempt seq a mid-stream; b decodes alone meanwhile
    let kept = pe.preempt(a).unwrap();
    assert_eq!(kept.len(), p1.len() + 3, "tokens kept across preempt");
    logits.remove(&a);
    for _ in 0..2 {
        let t2 = argmax(&logits[&b]);
        got.get_mut(&b).unwrap().push(t2);
        for (id, row) in
            pe.decode_step(&eng.rt, &[b], &[t2]).unwrap()
        {
            logits.insert(id, row);
        }
    }

    // resume: re-admit with everything it had, re-prefill (recompute)
    let a2 = 1000;
    pe.admit(a2, &kept).unwrap();
    loop {
        let out = pe.prefill_chunk(&eng.rt, &[a2], 32).unwrap();
        let (_, done, row) = out.into_iter().next().unwrap();
        if done {
            logits.insert(a2, row);
            break;
        }
    }

    // joint decode to the budget (a resumed at 3/8, b at 5/8)
    for _ in 0..3 {
        let (t1, t2) = (argmax(&logits[&a2]), argmax(&logits[&b]));
        got.get_mut(&a).unwrap().push(t1);
        if got[&b].len() < 8 {
            got.get_mut(&b).unwrap().push(t2);
            for (id, row) in pe
                .decode_step(&eng.rt, &[a2, b], &[t1, t2])
                .unwrap()
            {
                logits.insert(id, row);
            }
        } else {
            for (id, row) in
                pe.decode_step(&eng.rt, &[a2], &[t1]).unwrap()
            {
                logits.insert(id, row);
            }
        }
    }
    assert_eq!(got[&a], ref1[..6].to_vec(),
               "pipeline={pipeline}: preempt/resume changed seq a");
    assert_eq!(got[&b], ref2,
               "pipeline={pipeline}: survivor seq b diverged");
}

#[test]
fn preempt_resume_identical_pipeline_on() {
    preempt_resume_roundtrip(true);
}

#[test]
fn preempt_resume_identical_pipeline_off() {
    preempt_resume_roundtrip(false);
}

/// Fork interleaving: a child forked from a prefilled parent must
/// produce byte-identical logits to a freshly prefilled sequence with
/// the same prefix, when both are driven with the same token chain —
/// with the pipeline on and off.
fn fork_matches_fresh_prefill(pipeline: bool) {
    let Some(dir) = artifacts() else { return };
    let p = prompt(93, 32);
    let at = 21; // fork point (not page-aligned at page_size 8 → CoW)

    let mut eng =
        Engine::new(cfg(AttentionMode::Paged, &dir, pipeline)).unwrap();
    let parent = eng.fresh_seq_id();
    let pe = eng.paged.as_mut().unwrap();
    pe.admit(parent, &p).unwrap();
    let out = pe.prefill_chunk(&eng.rt, &[parent], 64).unwrap();
    assert!(out[0].1, "parent prefill finished");

    // fresh reference over the same prefix
    let fresh = 500;
    pe.admit(fresh, &p[..at]).unwrap();
    let out = pe.prefill_chunk(&eng.rt, &[fresh], 64).unwrap();
    assert!(out[0].1);
    let mut fresh_logits = out[0].2.clone();

    // fork the child at `at` (aliased full pages + CoW tail page;
    // drains any staged pipeline upload)
    let child = 501;
    pe.fork(parent, child, at).unwrap();

    // drive both with the fresh path's greedy chain; logits must match
    for step in 0..6 {
        let tok = argmax(&fresh_logits);
        let mut rows: HashMap<u64, Vec<f32>> = pe
            .decode_step(&eng.rt, &[fresh, child], &[tok, tok])
            .unwrap()
            .into_iter()
            .collect();
        let f = rows.remove(&fresh).unwrap();
        let c = rows.remove(&child).unwrap();
        assert_eq!(f, c,
                   "pipeline={pipeline} step {step}: forked child \
                    logits diverged from fresh prefill");
        fresh_logits = f;
    }
}

#[test]
fn fork_identical_pipeline_on() {
    fork_matches_fresh_prefill(true);
}

#[test]
fn fork_identical_pipeline_off() {
    fork_matches_fresh_prefill(false);
}

/// Cross-request prefix sharing must be invisible to decoded bytes:
/// a shared-prefix multi-tenant trace served with the radix prefix
/// cache on yields exactly the streams of a cache-off run, even
/// though most admissions alias previously registered pages.
#[test]
fn prefix_cache_on_off_streams_byte_identical() {
    let Some(dir) = artifacts() else { return };
    use paged_flex::sim::load::shared_prefix_trace;
    for seed in [5u64, 19] {
        let reqs: Vec<(u64, Vec<u32>, usize)> =
            shared_prefix_trace(seed, 512, 3, 4, 24, 8, 6)
                .into_iter()
                .map(|r| (r.id, r.prompt, r.max_new_tokens))
                .collect();
        let on = cfg(AttentionMode::Paged, &dir, true);
        let mut off = cfg(AttentionMode::Paged, &dir, true);
        assert!(on.prefix_cache, "cache is on by default");
        off.prefix_cache = false;
        let got_on = serve(on, &reqs);
        let got_off = serve(off, &reqs);
        for (id, _, _) in &reqs {
            assert_eq!(got_on[id], got_off[id],
                       "seed {seed} req {id}: prefix cache changed \
                        the tokens");
        }
    }
}

/// CoW fan-out: every child of a one-shot `fork_n` must produce
/// logits byte-identical to a freshly prefilled sequence over the
/// same prefix when driven with the same token chain.
fn fork_n_children_match_fresh(pipeline: bool) {
    let Some(dir) = artifacts() else { return };
    let p = prompt(94, 32);
    let at = 27; // partial tail → one CoW copy per child

    let mut eng =
        Engine::new(cfg(AttentionMode::Paged, &dir, pipeline)).unwrap();
    let parent = eng.fresh_seq_id();
    let pe = eng.paged.as_mut().unwrap();
    pe.admit(parent, &p).unwrap();
    let out = pe.prefill_chunk(&eng.rt, &[parent], 64).unwrap();
    assert!(out[0].1, "parent prefill finished");

    let fresh = 600;
    pe.admit(fresh, &p[..at]).unwrap();
    let out = pe.prefill_chunk(&eng.rt, &[fresh], 64).unwrap();
    assert!(out[0].1);
    let mut fresh_logits = out[0].2.clone();

    let kids = [601u64, 602, 603];
    let made = pe.fork_n(parent, &kids, at).unwrap();
    assert_eq!(made, kids.len(), "pool fits the whole fan");

    for step in 0..5 {
        let tok = argmax(&fresh_logits);
        let ids = [fresh, kids[0], kids[1], kids[2]];
        let mut rows: HashMap<u64, Vec<f32>> = pe
            .decode_step(&eng.rt, &ids, &[tok; 4])
            .unwrap()
            .into_iter()
            .collect();
        let f = rows.remove(&fresh).unwrap();
        for &kid in &kids {
            assert_eq!(rows.remove(&kid).unwrap(), f,
                       "pipeline={pipeline} step {step}: fanned \
                        child {kid} diverged from fresh prefill");
        }
        fresh_logits = f;
    }
}

#[test]
fn fork_n_identical_pipeline_on() {
    fork_n_children_match_fresh(true);
}

#[test]
fn fork_n_identical_pipeline_off() {
    fork_n_children_match_fresh(false);
}
