//! Coordinator + TCP server integration over real tiny artifacts.
//!
//! Exercises: continuous batching with mixed-length concurrent requests,
//! pool-pressure preemption with eventual completion, the full JSON-lines
//! wire protocol (tokens + text + stats + shutdown), and coordinator
//! admission validation.

use std::path::{Path, PathBuf};

use paged_flex::config::{AttentionMode, EngineConfig};
use paged_flex::coordinator::{Coordinator, Request};
use paged_flex::engine::Engine;
use paged_flex::server::{self, Client};
use paged_flex::trace::Rng;
use paged_flex::util::json::Value;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn cfg(dir: &Path) -> EngineConfig {
    let mut c = EngineConfig::default();
    c.model = "tiny".into();
    c.artifacts_dir = dir.to_path_buf();
    c.attention = AttentionMode::Paged;
    c.scheduler.max_batch_size = 2;
    c.scheduler.prefill_chunk = 32;
    c
}

fn prompt(seed: u64, len: usize) -> Vec<u32> {
    let mut rng = Rng::seeded(seed);
    (0..len).map(|_| rng.below(512) as u32).collect()
}

#[test]
fn coordinator_serves_mixed_batch_to_completion() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(cfg(&dir)).unwrap();
    let mut coord = Coordinator::new(engine);
    // mixed lengths, more requests than the batch size
    for (i, len) in [10usize, 25, 40, 18, 33].iter().enumerate() {
        coord
            .submit(Request::greedy(i as u64, prompt(i as u64, *len), 5))
            .unwrap();
    }
    let fins = coord.run_to_completion().unwrap();
    assert_eq!(fins.len(), 5);
    for f in &fins {
        assert!(f.error.is_none(), "request {} failed: {:?}", f.id,
                f.error);
        assert_eq!(f.tokens.len(), 5);
        let ttft = f.ttft_s.expect("finished with tokens has a TTFT");
        assert!(ttft >= 0.0 && f.total_s >= ttft);
    }
    let m = coord.metrics();
    assert_eq!(
        m.requests_finished.load(std::sync::atomic::Ordering::Relaxed), 5);
    assert_eq!(
        m.tokens_decoded.load(std::sync::atomic::Ordering::Relaxed), 25);
    // pool fully reclaimed
    let pe = coord.engine.paged.as_ref().unwrap();
    assert_eq!(pe.mgr.allocator().free_pages(),
               coord.engine.rt.spec().n_pages);
}

#[test]
fn pool_pressure_triggers_preemption_but_everything_finishes() {
    let Some(dir) = artifacts() else { return };
    let mut c = cfg(&dir);
    // tiny pool: 64 pages × 8 tokens = 512 pooled tokens; six 100-token
    // requests + generation cannot all fit at once
    c.scheduler.max_running_seqs = 8;
    let engine = Engine::new(c).unwrap();
    let mut coord = Coordinator::new(engine);
    for i in 0..6u64 {
        coord
            .submit(Request::greedy(i, prompt(i, 100), 8))
            .unwrap();
    }
    let fins = coord.run_to_completion().unwrap();
    assert_eq!(fins.len(), 6);
    for f in &fins {
        assert!(f.error.is_none());
        assert_eq!(f.tokens.len(), 8, "request {} truncated", f.id);
    }
    let pe = coord.engine.paged.as_ref().unwrap();
    assert_eq!(pe.mgr.allocator().free_pages(),
               coord.engine.rt.spec().n_pages, "pages leaked");
}

#[test]
fn preempted_request_matches_unpressured_output() {
    let Some(dir) = artifacts() else { return };
    // run the same request alone vs under pressure; greedy output must
    // be identical (recompute preemption is semantically invisible)
    let target = prompt(99, 80);

    let engine = Engine::new(cfg(&dir)).unwrap();
    let mut coord = Coordinator::new(engine);
    coord
        .submit(Request::greedy(0, target.clone(), 6))
        .unwrap();
    let alone = coord.run_to_completion().unwrap()[0].tokens.clone();

    let engine = Engine::new(cfg(&dir)).unwrap();
    let mut coord = Coordinator::new(engine);
    for i in 0..5u64 {
        coord
            .submit(Request::greedy(i, prompt(i, 90), 6))
            .unwrap();
    }
    coord.submit(Request::greedy(99, target, 6)).unwrap();
    let fins = coord.run_to_completion().unwrap();
    let under_pressure = fins
        .iter()
        .find(|f| f.id == 99)
        .unwrap()
        .tokens
        .clone();
    assert_eq!(alone, under_pressure,
               "preemption/recompute changed the output");
}

#[test]
fn coordinator_rejects_invalid_requests() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(cfg(&dir)).unwrap();
    let mut coord = Coordinator::new(engine);
    assert!(coord.submit(Request::greedy(1, vec![], 5)).is_err());
    // tiny max_seq_len = 128
    assert!(coord
        .submit(Request::greedy(2, prompt(0, 120), 20))
        .is_err());
    assert!(coord.idle());
}

#[test]
fn tcp_server_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let server_cfg = cfg(&dir);
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve_config(server_cfg, "127.0.0.1:0", move |bound| {
            addr_tx.send(bound).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();

    // token-level request
    let mut c1 = Client::connect(&addr).unwrap();
    let toks = c1.generate_tokens(&prompt(4, 20), 6).unwrap();
    assert_eq!(toks.len(), 6);

    // text-level request on a second connection
    let mut c2 = Client::connect(&addr).unwrap();
    let v = c2
        .request(&Value::obj(vec![
            ("op", Value::str("generate")),
            ("text", Value::str("paged attention")),
            ("max_new_tokens", Value::num(4.0)),
        ]))
        .unwrap();
    assert!(v.opt("error").is_none(), "{}", v.to_json());
    assert_eq!(v.get("tokens").unwrap().as_array().unwrap().len(), 4);
    assert!(v.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);

    // stats
    let stats = c2
        .request(&Value::obj(vec![("op", Value::str("stats"))]))
        .unwrap();
    assert!(stats.get("decode_tok_per_s").unwrap().as_f64().unwrap()
            >= 0.0);

    // malformed op
    let bad = c2
        .request(&Value::obj(vec![("op", Value::str("nonsense"))]))
        .unwrap();
    assert!(bad.opt("error").is_some());

    c2.shutdown().unwrap();
    handle.join().unwrap();
}
