//! Runtime ⇄ artifact bridge smoke tests (need `make artifacts` first;
//! every test no-ops gracefully on a fresh checkout).
//!
//! Verifies the full AOT path end to end: HLO text loads, PJRT compiles,
//! device-resident weights bind, tuple outputs split, and two
//! *independent* executables (nocache vs full-logits) agree numerically —
//! the Rust-level half of the paper's numerical-equivalence claim.

use std::path::{Path, PathBuf};

use paged_flex::runtime::{HostTensor, Runtime};
use paged_flex::trace::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn prompt_tokens(rng: &mut Rng, n: usize, vocab: u32) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[test]
fn nocache_matches_full_logits_row() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny").unwrap();
    let vocab = rt.spec().vocab_size;

    let mut rng = Rng::seeded(11);
    let toks = prompt_tokens(&mut rng, 64, vocab as u32);
    let seq_len = 40usize; // live prefix; rest is padding

    let t_tokens = HostTensor::i32(toks.clone(), vec![1, 64]);
    let t_lens = HostTensor::scalar_i32_vec(&[seq_len as i32]);

    let out = rt
        .run("nocache_s64", &[t_tokens.clone(), t_lens.clone()])
        .unwrap();
    assert_eq!(out.len(), 1);
    let nocache_logits = out[0].as_f32().unwrap().to_vec();
    assert_eq!(nocache_logits.len(), vocab);

    let out = rt.run("logits_s64", &[t_tokens, t_lens]).unwrap();
    let full = out[0].as_f32().unwrap();
    assert_eq!(full.len(), 64 * vocab);
    let row = &full[(seq_len - 1) * vocab..seq_len * vocab];

    let max_err = nocache_logits
        .iter()
        .zip(row)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-3, "nocache vs logits row: max err {max_err}");
    // and the logits are non-degenerate
    let spread = nocache_logits.iter().fold(f32::MIN, |m, &x| m.max(x))
        - nocache_logits.iter().fold(f32::MAX, |m, &x| m.min(x));
    assert!(spread > 0.1, "degenerate logits, spread {spread}");
}

#[test]
fn run_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny").unwrap();
    let vocab = rt.spec().vocab_size;
    let mut rng = Rng::seeded(3);
    let toks = prompt_tokens(&mut rng, 64, vocab as u32);
    let inputs = [
        HostTensor::i32(toks, vec![1, 64]),
        HostTensor::scalar_i32_vec(&[64]),
    ];
    let a = rt.run("nocache_s64", &inputs).unwrap();
    let b = rt.run("nocache_s64", &inputs).unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny").unwrap();
    let bad = [
        HostTensor::i32(vec![0; 32], vec![1, 32]), // wrong seq len
        HostTensor::scalar_i32_vec(&[32]),
    ];
    let err = rt.run("nocache_s64", &bad).unwrap_err().to_string();
    assert!(err.contains("shape"), "got: {err}");
    let err = rt.run("bogus_artifact", &[]).unwrap_err().to_string();
    assert!(err.contains("unknown artifact"), "got: {err}");
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny").unwrap();
    rt.executable("logits_s64").unwrap();
    rt.executable("logits_s64").unwrap();
    assert_eq!(
        rt.compile_log()
            .iter()
            .filter(|(n, _)| n == "logits_s64")
            .count(),
        1,
        "second request must hit the cache"
    );
}
