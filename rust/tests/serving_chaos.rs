//! Serving-tier chaos conformance (DESIGN.md §12), the overload
//! mirror of `chaos_recovery.rs`.
//!
//! Two layers:
//!
//! * **Offline storms (always run).** A deterministic tick-based
//!   replica of the coordinator's overload machinery — KV-budget
//!   admission with hysteresis, deadline expiry, bounded
//!   retry-with-backoff, the Accept → DeferPrefill → ShedNewest →
//!   RejectAll ladder — is driven by seeded [`ServingFaultPlan`]
//!   schedules (client disconnects, request bursts, slow readers).
//!   Under every schedule the run must drain, every request must end
//!   with tokens or a typed reason, the page pool must come back
//!   whole, and every overload counter must be monotone (I11). A
//!   fault-free low-rate control must show zero shed activity.
//!
//! * **TCP storms (artifact-gated).** The same properties through the
//!   real JSON-lines server over real tiny artifacts: an
//!   overcommitted generation storm drains with typed outcomes only;
//!   chaos clients (mid-generate disconnects, connection bursts, slow
//!   readers) leave the survivors' token streams byte-identical to a
//!   fault-free replica; graceful drain answers every client instead
//!   of leaving one blocked; over-cap connections get a typed
//!   `overloaded` refusal; streamed replies (PR 8) concatenate to the
//!   exact greedy stream and still terminate through a drain.
//!
//! `PF_FAULT_SEED=S` narrows the seed sweep to one schedule (the CI
//! serving-chaos matrix).

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use paged_flex::coordinator::{backoff_ticks, estimate_pages,
                              overload_pressure, AdmissionGate,
                              OverloadLadder, ShedLevel};
use paged_flex::kvpage::{AllocError, GrowthPolicy, PageAllocator,
                         PageManager};
use paged_flex::metrics::ServingMetrics;
use paged_flex::runtime::{ServingFaultInjector, ServingFaultKind,
                          ServingFaultPlan};
use paged_flex::trace::Rng;

const PAGE_SIZE: usize = 8;

/// `PF_FAULT_SEED=S` → run just that schedule (the CI serving-chaos
/// matrix); unset → sweep the defaults.
fn fault_seeds(defaults: &[u64]) -> Vec<u64> {
    match std::env::var("PF_FAULT_SEED")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
    {
        Some(s) => vec![s],
        None => defaults.to_vec(),
    }
}

// ---------------------------------------------------------------
// offline overload rig
// ---------------------------------------------------------------

struct RigCfg {
    n_pages: u32,
    max_running: usize,
    max_waiting: usize,
    max_retries: u32,
    deadline: u64,
    queue_high: usize,
    queue_low: usize,
    low_pages: usize,
    high_pages: usize,
    watermark: usize,
    prompt_len: usize,
    max_new: usize,
}

const STORM_RIG: RigCfg = RigCfg {
    n_pages: 32,
    max_running: 4,
    max_waiting: 32,
    max_retries: 3,
    deadline: 120,
    queue_high: 10,
    queue_low: 4,
    low_pages: 4,
    high_pages: 8,
    watermark: 2,
    prompt_len: 24,
    max_new: 8,
};

struct RigJob {
    id: u64,
    arrive: u64,
    generated: usize,
    retries: u32,
    not_before: u64,
}

struct RigOut {
    /// request id → Ok(token count) | Err(typed reason)
    outcomes: HashMap<u64, Result<usize, &'static str>>,
    drained: bool,
    free_end: usize,
    injected: u64,
    violations: Vec<String>,
    shed: u64,
    expired: u64,
    sat_retries: u64,
    demotes: u64,
    repromotes: u64,
    deferrals: u64,
    rejected: u64,
}

/// Deterministic replica of the coordinator's overload tick: faults →
/// arrivals → expiry → ladder/shed → budget admission → decode →
/// retire, with the same forced-progress and bounded-retry rules.
fn run_rig(rc: &RigCfg, n_jobs: usize, arrival_every: u64,
           plan: ServingFaultPlan) -> RigOut {
    let n_events = plan.events().len() as u64;
    let mut inj = ServingFaultInjector::new(plan);
    let m = ServingMetrics::new();
    let alloc = Arc::new(PageAllocator::new(
        rc.n_pages, PAGE_SIZE, 16, GrowthPolicy::Exact));
    let mut mgr = PageManager::new(Arc::clone(&alloc), 64);
    mgr.set_prefix_cache(false); // ramp prompts would all alias

    let mut ladder = OverloadLadder::new();
    let mut gate = AdmissionGate::new();
    let mut waiting: VecDeque<RigJob> = VecDeque::new();
    let mut running: Vec<RigJob> = Vec::new();
    let mut outcomes: HashMap<u64, Result<usize, &'static str>> =
        HashMap::new();
    let mut violations = Vec::new();
    let mut last_snap = [0u64; 7];
    let mut next_burst_id = n_jobs as u64;
    let mut arrived = 0usize;
    let mut stalled: Option<u64> = None;
    let cap = 5_000u64;
    let mut tick = 0u64;

    loop {
        // serving faults land first, like wire events beating the tick
        let mut arrivals: Vec<u64> = Vec::new();
        for kind in inj.begin_step() {
            match kind {
                ServingFaultKind::Burst => {
                    for _ in 0..4 {
                        arrivals.push(next_burst_id);
                        next_burst_id += 1;
                    }
                }
                ServingFaultKind::ClientDisconnect => {
                    // newest running client vanishes mid-generate
                    if let Some(i) = running
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, j)| (j.arrive, j.id))
                        .map(|(i, _)| i)
                    {
                        let job = running.swap_remove(i);
                        mgr.free(job.id).unwrap();
                        outcomes.insert(job.id, Err("cancelled"));
                    }
                }
                ServingFaultKind::SlowReader => {
                    stalled = running.first().map(|j| j.id);
                }
            }
        }
        while arrived < n_jobs
            && tick >= arrived as u64 * arrival_every
        {
            arrivals.push(arrived as u64);
            arrived += 1;
        }
        for id in arrivals {
            if ladder.level() == ShedLevel::RejectAll {
                ServingMetrics::inc(&m.requests_rejected, 1);
                ServingMetrics::inc(&m.requests_shed, 1);
                outcomes.insert(id, Err("overloaded"));
            } else if waiting.len() >= rc.max_waiting {
                ServingMetrics::inc(&m.requests_rejected, 1);
                outcomes.insert(id, Err("queue_full"));
            } else {
                waiting.push_back(RigJob {
                    id, arrive: tick, generated: 0, retries: 0,
                    not_before: 0,
                });
            }
        }

        // deadline expiry (waiting then running), then the ladder
        let mut i = 0;
        while i < waiting.len() {
            if tick - waiting[i].arrive >= rc.deadline {
                let job = waiting.remove(i).unwrap();
                ServingMetrics::inc(&m.requests_expired, 1);
                outcomes.insert(job.id, Err("expired"));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < running.len() {
            if tick - running[i].arrive >= rc.deadline {
                let job = running.swap_remove(i);
                mgr.free(job.id).unwrap();
                ServingMetrics::inc(&m.requests_expired, 1);
                outcomes.insert(job.id, Err("expired"));
            } else {
                i += 1;
            }
        }
        let level = ladder.note_tick(overload_pressure(
            waiting.len(), rc.queue_high, alloc.free_pages(),
            rc.low_pages));
        if level >= ShedLevel::ShedNewest {
            while waiting.len() > rc.queue_low {
                let job = waiting.pop_back().unwrap();
                ServingMetrics::inc(&m.requests_shed, 1);
                outcomes.insert(job.id, Err("overloaded"));
            }
        }
        m.shed_demotes.store(ladder.demotes(), Relaxed);
        m.shed_repromotes.store(ladder.repromotes(), Relaxed);

        // budget admission with forced progress + bounded retries
        while running.len() < rc.max_running {
            if level >= ShedLevel::DeferPrefill && !running.is_empty()
            {
                break;
            }
            let ready = waiting
                .front()
                .map(|j| j.not_before <= tick)
                .unwrap_or(false);
            if !ready {
                break;
            }
            let free = alloc.free_pages();
            let open =
                gate.evaluate(free, rc.low_pages, rc.high_pages);
            let job = waiting.front().unwrap();
            let est = estimate_pages(
                rc.prompt_len + job.generated,
                rc.max_new - job.generated, PAGE_SIZE);
            let fits = free >= est + rc.watermark;
            if (!open || !fits) && !running.is_empty() {
                gate.note_deferral();
                ServingMetrics::inc(&m.admission_deferrals, 1);
                break;
            }
            let mut job = waiting.pop_front().unwrap();
            let ctx: Vec<u32> =
                (0..(rc.prompt_len + job.generated) as u32).collect();
            match mgr.reserve(job.id, &ctx) {
                Ok(_) => {
                    mgr.note_assigned(job.id, ctx.len()).unwrap();
                    ServingMetrics::inc(&m.requests_admitted, 1);
                    running.push(job);
                }
                Err(AllocError::PoolExhausted { .. }) => {
                    if job.retries >= rc.max_retries {
                        outcomes.insert(job.id, Err("saturated"));
                    } else {
                        job.retries += 1;
                        job.not_before =
                            tick + backoff_ticks(job.retries);
                        ServingMetrics::inc(&m.saturated_retries, 1);
                        waiting.push_front(job);
                    }
                    break;
                }
                Err(e) => {
                    violations.push(format!("req {}: {e}", job.id));
                    outcomes.insert(job.id, Err("internal"));
                    break;
                }
            }
        }

        // decode one token per running seq; a slow reader stalls its
        // victim for the tick (pages held, no progress)
        let mut i = 0;
        while i < running.len() {
            if stalled == Some(running[i].id) {
                i += 1;
                continue;
            }
            match mgr.prepare_append(running[i].id, 1) {
                Ok(_) => {
                    mgr.note_assigned(running[i].id, 1).unwrap();
                    running[i].generated += 1;
                    if running[i].generated >= rc.max_new {
                        let job = running.swap_remove(i);
                        mgr.free(job.id).unwrap();
                        ServingMetrics::inc(&m.requests_finished, 1);
                        outcomes
                            .insert(job.id, Ok(job.generated));
                        continue;
                    }
                }
                Err(AllocError::PoolExhausted { .. }) => {
                    let mut job = running.swap_remove(i);
                    mgr.free(job.id).unwrap();
                    if job.retries >= rc.max_retries {
                        outcomes.insert(job.id, Err("saturated"));
                    } else {
                        job.retries += 1;
                        job.not_before =
                            tick + backoff_ticks(job.retries);
                        ServingMetrics::inc(&m.saturated_retries, 1);
                        waiting.push_front(job);
                    }
                    continue;
                }
                Err(e) => {
                    let job = running.swap_remove(i);
                    mgr.free(job.id).unwrap();
                    violations.push(format!("req {}: {e}", job.id));
                    outcomes.insert(job.id, Err("internal"));
                    continue;
                }
            }
            i += 1;
        }
        stalled = None;

        // I11: the overload counter set never moves backwards
        let snap = [
            m.requests_shed.load(Relaxed),
            m.requests_expired.load(Relaxed),
            m.saturated_retries.load(Relaxed),
            m.shed_demotes.load(Relaxed),
            m.shed_repromotes.load(Relaxed),
            m.admission_deferrals.load(Relaxed),
            m.requests_rejected.load(Relaxed),
        ];
        if snap.iter().zip(&last_snap).any(|(a, b)| a < b) {
            violations.push(format!(
                "tick {tick}: counters regressed {last_snap:?} -> \
                 {snap:?}"));
        }
        last_snap = snap;

        let drained = arrived >= n_jobs && waiting.is_empty()
            && running.is_empty();
        if (drained && inj.injected() >= n_events) || tick >= cap {
            break;
        }
        tick += 1;
    }

    RigOut {
        drained: arrived >= n_jobs && waiting.is_empty()
            && running.is_empty(),
        free_end: alloc.free_pages(),
        injected: inj.injected(),
        violations,
        shed: m.requests_shed.load(Relaxed),
        expired: m.requests_expired.load(Relaxed),
        sat_retries: m.saturated_retries.load(Relaxed),
        demotes: m.shed_demotes.load(Relaxed),
        repromotes: m.shed_repromotes.load(Relaxed),
        deferrals: m.admission_deferrals.load(Relaxed),
        rejected: m.requests_rejected.load(Relaxed),
        outcomes,
    }
}

const TYPED: &[&str] = &["overloaded", "queue_full", "expired",
                         "saturated", "cancelled"];

#[test]
fn serving_plans_replay_and_differ_across_seeds() {
    let mut schedules = Vec::new();
    for seed in [3u64, 17, 29] {
        let a = ServingFaultPlan::seeded(seed, 64, 10);
        assert_eq!(a, ServingFaultPlan::seeded(seed, 64, 10),
                   "seed {seed} must replay identically");
        assert_eq!(
            a,
            ServingFaultPlan::parse(&format!("seed:{seed}:64:10"))
                .unwrap(),
            "parse(seed:...) must be the seeded constructor");
        assert_eq!(a.events().len(), 10);
        assert!(a.events().iter().all(|e| e.step < 64));
        assert!(a.events().windows(2).all(|w| w[0].step <= w[1].step));
        // the injector fires each event exactly once, then goes clean
        let mut inj = ServingFaultInjector::new(a.clone());
        let mut fired = 0;
        for _ in 0..96 {
            fired += inj.begin_step().len();
        }
        assert_eq!(fired, 10);
        assert_eq!(inj.injected(), 10);
        schedules.push(a);
    }
    assert!(schedules.windows(2).any(|w| w[0] != w[1]),
            "different seeds must yield different storms");
}

#[test]
fn offline_storms_drain_typed_with_monotone_counters() {
    for seed in fault_seeds(&[3, 17, 29]) {
        let plan = ServingFaultPlan::seeded(seed, 64, 10);
        let out = run_rig(&STORM_RIG, 24, 2, plan);
        assert!(out.violations.is_empty(),
                "seed {seed}: {:?}", out.violations);
        assert!(out.drained, "seed {seed}: storm did not drain");
        assert_eq!(out.injected, 10,
                   "seed {seed}: schedule only partially fired");
        assert_eq!(out.free_end, STORM_RIG.n_pages as usize,
                   "seed {seed}: pages leaked");
        // every request — base arrivals and burst extras — ended in
        // tokens or a typed reason
        assert!(out.outcomes.len() >= 24);
        for (id, o) in &out.outcomes {
            match o {
                Ok(n) => assert_eq!(*n, STORM_RIG.max_new,
                                    "req {id} finished short"),
                Err(why) => assert!(TYPED.contains(why),
                                    "req {id}: untyped end '{why}'"),
            }
        }
    }
}

#[test]
fn fault_free_low_rate_control_is_silent() {
    // under-capacity arrivals, no faults: the overload machinery must
    // be a strict no-op — zero shed, expiry, retries, deferrals
    let out = run_rig(&STORM_RIG, 24, 3, ServingFaultPlan::none());
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert!(out.drained);
    assert_eq!(out.free_end, STORM_RIG.n_pages as usize);
    assert_eq!(out.outcomes.len(), 24);
    assert!(out.outcomes.values().all(|o| o == &Ok(STORM_RIG.max_new)),
            "calm run must finish everything");
    assert_eq!(
        (out.shed, out.expired, out.sat_retries, out.demotes,
         out.repromotes, out.deferrals, out.rejected),
        (0, 0, 0, 0, 0, 0, 0),
        "zero-overload run reported overload activity");
}

#[test]
fn saturated_retirement_is_bounded_and_counted() {
    // a request that can never fit the pool must retry exactly
    // max_retries times with doubling backoff, then die typed —
    // never loop forever, never abort the run
    let rc = RigCfg {
        n_pages: 2, // 16 pooled tokens << prompt_len
        max_running: 2,
        ..STORM_RIG
    };
    let out = run_rig(&rc, 1, 1, ServingFaultPlan::none());
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert!(out.drained, "saturated request must not wedge the rig");
    assert_eq!(out.outcomes.get(&0), Some(&Err("saturated")));
    assert_eq!(out.sat_retries, rc.max_retries as u64,
               "retry count must be exact, then typed retirement");
    assert_eq!(out.free_end, rc.n_pages as usize);
}

// ---------------------------------------------------------------
// TCP storms over real tiny artifacts
// ---------------------------------------------------------------

use paged_flex::config::{AttentionMode, EngineConfig};
use paged_flex::server::{self, Client};
use paged_flex::util::json::{parse, Value};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn cfg(dir: &Path) -> EngineConfig {
    let mut c = EngineConfig::default();
    c.model = "tiny".into();
    c.artifacts_dir = dir.to_path_buf();
    c.attention = AttentionMode::Paged;
    c.scheduler.max_batch_size = 2;
    c.scheduler.prefill_chunk = 32;
    c
}

fn prompt(seed: u64, len: usize) -> Vec<u32> {
    let mut rng = Rng::seeded(seed);
    (0..len).map(|_| rng.below(512) as u32).collect()
}

fn spawn_server(cfg: EngineConfig)
                -> (String, std::thread::JoinHandle<()>) {
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        server::serve_config(cfg, "127.0.0.1:0", move |bound| {
            addr_tx.send(bound).unwrap();
        })
        .unwrap();
    });
    (addr_rx.recv().unwrap().to_string(), handle)
}

fn gen_body(p: &[u32], max_new: usize) -> Value {
    Value::obj(vec![
        ("op", Value::str("generate")),
        ("prompt",
         Value::arr(p.iter().map(|&t| Value::num(t as f64)))),
        ("max_new_tokens", Value::num(max_new as f64)),
    ])
}

/// Satellite: overcommitted generation storm through the wire. Six
/// gen-heavy requests whose end-to-end KV need (6 × 16 pages) is 1.5×
/// the 64-page pool are all admissible up front (each reserves one
/// prompt page); the pool dries mid-decode. Whatever mix of
/// preemption, bounded saturated retries, and shed the coordinator
/// picks, every client must get a terminal line — full tokens or a
/// typed reason — and the pool must come back whole.
#[test]
fn overcommit_storm_drains_typed_over_tcp() {
    let Some(dir) = artifacts() else { return };
    let mut c = cfg(&dir);
    c.scheduler.max_running_seqs = 8;
    c.scheduler.max_sat_retries = 1;
    let (addr, handle) = spawn_server(c);

    let mut stats0 = Client::connect(&addr).unwrap();
    let free_full = stats0
        .request(&Value::obj(vec![("op", Value::str("stats"))]))
        .unwrap()
        .get("free_pages").unwrap()
        .as_u64().unwrap();

    let workers: Vec<_> = (0..6u64)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                cl.request(&gen_body(&prompt(i, 8), 120)).unwrap()
            })
        })
        .collect();
    let replies: Vec<Value> =
        workers.into_iter().map(|w| w.join().unwrap()).collect();

    let mut done = 0;
    for v in &replies {
        if v.opt("error").is_some() {
            let reason = v.get("reason").unwrap().as_str().unwrap();
            assert!(TYPED.contains(&reason),
                    "untyped failure line: {}", v.to_json());
            v.get("retryable").unwrap().as_bool().unwrap();
        } else {
            assert_eq!(
                v.get("tokens").unwrap().as_array().unwrap().len(),
                120, "short stream: {}", v.to_json());
            done += 1;
        }
    }
    assert!(done >= 1, "storm starved every request");

    let stats = stats0
        .request(&Value::obj(vec![("op", Value::str("stats"))]))
        .unwrap();
    assert_eq!(stats.get("waiting").unwrap().as_u64().unwrap(), 0);
    assert_eq!(stats.get("running").unwrap().as_u64().unwrap(), 0);
    assert_eq!(stats.get("free_pages").unwrap().as_u64().unwrap(),
               free_full, "pages leaked across the storm");

    stats0.shutdown().unwrap();
    handle.join().unwrap();
}

/// Chaos clients vs a fault-free replica: seeded disconnects, bursts
/// and slow readers may cost latency but the surviving clients'
/// greedy token streams must match the clean run byte for byte.
#[test]
fn disconnect_chaos_matches_fault_free_replica() {
    let Some(dir) = artifacts() else { return };
    let reqs: Vec<Vec<u32>> = (0..10u64)
        .map(|i| prompt(100 + i, 12 + (i as usize % 3) * 8))
        .collect();

    // clean replica: sequential, unfaulted
    let (addr, handle) = spawn_server(cfg(&dir));
    let mut cl = Client::connect(&addr).unwrap();
    let expected: Vec<Vec<u32>> = reqs
        .iter()
        .map(|p| cl.generate_tokens(p, 5).unwrap())
        .collect();
    cl.shutdown().unwrap();
    handle.join().unwrap();

    for seed in fault_seeds(&[3, 17, 29]) {
        let mut inj = ServingFaultInjector::new(
            ServingFaultPlan::seeded(seed, 10, 5));
        let (addr, handle) = spawn_server(cfg(&dir));
        let mut workers = Vec::new();
        for (i, p) in reqs.iter().enumerate() {
            let fired = inj.begin_step();
            if fired.contains(&ServingFaultKind::Burst) {
                // connection burst: ephemeral stats clients
                for _ in 0..2 {
                    let mut b = Client::connect(&addr).unwrap();
                    b.request(&Value::obj(vec![
                        ("op", Value::str("stats"))])).unwrap();
                }
            }
            if fired.contains(&ServingFaultKind::ClientDisconnect) {
                // fire the request and vanish mid-generate: the
                // server must carry on; nobody reads the reply
                use std::io::Write as _;
                let mut s =
                    std::net::TcpStream::connect(&addr).unwrap();
                s.write_all(gen_body(p, 5).to_json().as_bytes())
                    .unwrap();
                s.write_all(b"\n").unwrap();
                s.flush().unwrap();
                drop(s);
                continue;
            }
            let slow =
                fired.contains(&ServingFaultKind::SlowReader);
            let addr = addr.clone();
            let p = p.clone();
            workers.push((i, std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                if slow {
                    std::thread::sleep(
                        std::time::Duration::from_millis(80));
                }
                cl.generate_tokens(&p, 5).unwrap()
            })));
        }
        for (i, w) in workers {
            let toks = w.join().unwrap();
            assert_eq!(toks, expected[i],
                       "seed {seed}: request {i} diverged from the \
                        fault-free replica");
        }
        let mut cl = Client::connect(&addr).unwrap();
        let stats = cl
            .request(&Value::obj(vec![("op", Value::str("stats"))]))
            .unwrap();
        assert_eq!(stats.get("waiting").unwrap().as_u64().unwrap(),
                   0, "seed {seed}: requests stuck after chaos");
        assert_eq!(stats.get("running").unwrap().as_u64().unwrap(),
                   0);
        cl.shutdown().unwrap();
        handle.join().unwrap();
    }
}

/// Graceful drain: shutdown lets the in-flight request finish with
/// its full token stream while a request submitted after shutdown
/// gets an immediate terminal error line — no client is left blocked
/// on a reply that will never come.
#[test]
fn graceful_drain_answers_every_client() {
    let Some(dir) = artifacts() else { return };
    let (addr, handle) = spawn_server(cfg(&dir));

    let in_flight = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            cl.generate_tokens(&prompt(7, 20), 60).unwrap()
        })
    };
    // late client connects BEFORE shutdown (so its reader thread
    // exists) but submits after; the in-flight request is admitted
    // well before the stop flag lands
    let mut late = Client::connect(&addr).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut sd = Client::connect(&addr).unwrap();
    sd.shutdown().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));

    let v = late.request(&gen_body(&prompt(8, 10), 4)).unwrap();
    assert!(v.opt("error").is_some(),
            "post-shutdown submit must not run: {}", v.to_json());
    // mid-drain the coordinator answers with a typed retryable
    // `overloaded`; once it has exited the reader thread answers
    // itself ("server stopped", reason internal) — both are terminal
    // lines, which is the property (the client must never hang)
    let reason = v.get("reason").unwrap().as_str().unwrap();
    match reason {
        "overloaded" => {
            assert!(v.get("retryable").unwrap().as_bool().unwrap(),
                    "drain refusals are retryable elsewhere");
        }
        "internal" => {
            let msg = v.get("error").unwrap().as_str().unwrap();
            assert!(msg.contains("stopped")
                        || msg.contains("shutting down"),
                    "untyped drain refusal: {}", v.to_json());
        }
        other => panic!("unexpected drain reason '{other}': {}",
                        v.to_json()),
    }

    assert_eq!(in_flight.join().unwrap().len(), 60,
               "in-flight request truncated by drain");
    handle.join().unwrap();
}

/// Connection cap: the over-cap client gets a typed refusal line at
/// accept instead of a silent hang or an unbounded reader thread.
#[test]
fn over_cap_connection_gets_typed_refusal() {
    let Some(dir) = artifacts() else { return };
    let mut c = cfg(&dir);
    c.scheduler.max_connections = 1;
    let (addr, handle) = spawn_server(c);

    let mut first = Client::connect(&addr).unwrap();
    first
        .request(&Value::obj(vec![("op", Value::str("stats"))]))
        .unwrap();

    // read-only raw stream: the refusal line arrives unprompted at
    // accept (writing first could race the server-side close into an
    // RST that drops the buffered refusal)
    let second = std::net::TcpStream::connect(&addr).unwrap();
    let mut line = String::new();
    use std::io::BufRead as _;
    std::io::BufReader::new(second).read_line(&mut line).unwrap();
    let v = parse(&line).unwrap();
    assert!(v.opt("error").is_some(), "{}", v.to_json());
    assert_eq!(v.get("reason").unwrap().as_str().unwrap(),
               "overloaded");
    assert!(v.get("retryable").unwrap().as_bool().unwrap());

    drop(first); // slot frees when the reader thread exits
    std::thread::sleep(std::time::Duration::from_millis(100));
    let mut third = Client::connect(&addr).unwrap();
    third
        .request(&Value::obj(vec![("op", Value::str("stats"))]))
        .unwrap();
    third.shutdown().unwrap();
    handle.join().unwrap();
}

fn stream_body(p: &[u32], max_new: usize) -> Value {
    Value::obj(vec![
        ("op", Value::str("generate")),
        ("prompt",
         Value::arr(p.iter().map(|&t| Value::num(t as f64)))),
        ("max_new_tokens", Value::num(max_new as f64)),
        ("stream", Value::Bool(true)),
    ])
}

/// Streaming conformance (DESIGN.md §13): the chunk lines concatenate
/// to exactly the non-streamed greedy stream for the same prompt,
/// every chunk is marked `"stream":true` and names the request, and
/// the terminal line is typed — `done:true`, the full token list, a
/// TTFT, and no `"stream"` key for clients that split on it.
#[test]
fn streamed_chunks_concatenate_to_the_greedy_stream() {
    let Some(dir) = artifacts() else { return };
    let (addr, handle) = spawn_server(cfg(&dir));
    let p = prompt(42, 16);

    let mut cl = Client::connect(&addr).unwrap();
    let expected = cl.generate_tokens(&p, 12).unwrap();
    assert_eq!(expected.len(), 12);

    let (chunks, term) =
        cl.request_stream(&stream_body(&p, 12)).unwrap();
    assert!(term.opt("error").is_none(), "{}", term.to_json());
    assert!(!chunks.is_empty(), "streamed run produced no chunks");
    let id = term.get("id").unwrap().as_u64().unwrap();
    let mut streamed: Vec<u32> = Vec::new();
    for ch in &chunks {
        assert!(ch.get("stream").unwrap().as_bool().unwrap());
        assert_eq!(ch.get("id").unwrap().as_u64().unwrap(), id,
                   "chunk names a different request");
        assert!(ch.opt("done").is_none(),
                "chunks must not carry the terminal marker");
        for t in ch.get("tokens").unwrap().as_array().unwrap() {
            streamed.push(t.as_u64().unwrap() as u32);
        }
    }
    assert_eq!(streamed, expected,
               "chunk concatenation diverged from the greedy stream");
    assert!(term.get("done").unwrap().as_bool().unwrap());
    assert!(term.opt("stream").is_none(),
            "terminal line must not be marked as a chunk");
    let full: Vec<u32> = term
        .get("tokens").unwrap().as_array().unwrap()
        .iter()
        .map(|t| t.as_u64().unwrap() as u32)
        .collect();
    assert_eq!(full, expected,
               "terminal token list diverged from the stream");
    assert!(term.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);

    cl.shutdown().unwrap();
    handle.join().unwrap();
}

/// Graceful drain composes with streaming: an in-flight streamed
/// request keeps its chunks flowing through the drain and ends with a
/// real `done:true` terminal carrying every token, while a streamed
/// submit after shutdown gets a typed terminal error line and zero
/// chunks — no streaming client is ever left blocked mid-stream.
#[test]
fn graceful_drain_answers_a_mid_stream_client() {
    let Some(dir) = artifacts() else { return };
    let (addr, handle) = spawn_server(cfg(&dir));

    let in_flight = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            cl.request_stream(&stream_body(&prompt(9, 20), 60))
                .unwrap()
        })
    };
    // late client connects BEFORE shutdown (reader thread exists)
    // but submits after; the in-flight stream is admitted well
    // before the stop flag lands
    let mut late = Client::connect(&addr).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut sd = Client::connect(&addr).unwrap();
    sd.shutdown().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));

    let (late_chunks, late_term) = late
        .request_stream(&stream_body(&prompt(10, 8), 4))
        .unwrap();
    assert!(late_chunks.is_empty(),
            "post-shutdown stream must not produce tokens");
    assert!(late_term.opt("error").is_some(),
            "post-shutdown submit must end typed: {}",
            late_term.to_json());

    let (chunks, term) = in_flight.join().unwrap();
    assert!(term.get("done").unwrap().as_bool().unwrap(),
            "drain must let the in-flight stream finish: {}",
            term.to_json());
    let n: usize = chunks
        .iter()
        .map(|c| {
            c.get("tokens").unwrap().as_array().unwrap().len()
        })
        .sum();
    assert_eq!(n, 60, "in-flight stream truncated by drain");
    handle.join().unwrap();
}
