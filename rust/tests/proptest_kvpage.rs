//! Randomized property tests over the page-manager state machine.
//!
//! No proptest crate offline, so this drives the invariants with an
//! in-tree PRNG across many seeds: thousands of random RESERVE / APPEND /
//! FORK / FREE interleavings, with full-state invariant checks after
//! every step. Failures print the seed + step for replay.
//!
//! Invariants (DESIGN.md §7, §15):
//!  I1  page conservation: free + referenced-by-tables-or-cache ==
//!      capacity (cached prefix pages are physically held)
//!  I2  no page appears in two tables unless its refcount covers it
//!  I3  every table's mapped capacity covers its live tokens
//!  I4  audit: reserved bytes == physically-held pages × page bytes
//!  I5  after all FREEs + a cache flush, the pool is fully free and
//!      audit is zero
//!  I13 refcount + prefix-index + window-slot agreement under random
//!      share/fork/unshare/preempt/quarantine interleavings

use std::collections::HashMap;
use std::sync::Arc;

use paged_flex::kvpage::{
    AllocError, GrowthPolicy, HostPool, PageAllocator, PageManager,
    PoolGeometry, ResidentWindow, UploadPlan,
};
use paged_flex::runtime::DeviceWindow;
use paged_flex::trace::Rng;

const N_PAGES: u32 = 48;
const PAGE_SIZE: usize = 8;
const BYTES_PER_TOKEN: u64 = 16;
const MAX_BLOCKS: usize = 12;

struct Harness {
    mgr: PageManager,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
}

impl Harness {
    fn new(seed: u64, policy: GrowthPolicy) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        Harness {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            live: vec![],
            next_id: 1,
            rng: Rng::seeded(seed),
        }
    }

    fn random_prompt(&mut self) -> Vec<u32> {
        let len = 1 + self.rng.below(60) as usize;
        (0..len).map(|_| self.rng.below(512) as u32).collect()
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(10) {
            // RESERVE (40%)
            0..=3 => {
                let id = self.next_id;
                let prompt = self.random_prompt();
                match self.mgr.reserve(id, &prompt) {
                    Ok(out) => {
                        self.next_id += 1;
                        self.live.push(id);
                        let fresh = prompt.len() - out.cached_tokens;
                        self.mgr.note_assigned(id, fresh).unwrap();
                        // register some prefixes to stir sharing
                        if self.rng.below(2) == 0 {
                            self.mgr.register_prefix(id, &prompt).unwrap();
                        }
                    }
                    Err(AllocError::PoolExhausted { .. })
                    | Err(AllocError::CapacityExceeded { .. }) => {}
                    Err(e) => panic!("{ctx}: reserve failed oddly: {e}"),
                }
            }
            // APPEND (30%)
            4..=6 => {
                if let Some(&id) = pick(&mut self.rng, &self.live) {
                    let extra = 1 + self.rng.below(12) as usize;
                    match self.mgr.prepare_append(id, extra) {
                        Ok(_) => self.mgr.note_assigned(id, extra).unwrap(),
                        Err(AllocError::PoolExhausted { .. })
                        | Err(AllocError::CapacityExceeded { .. }) => {}
                        Err(e) => panic!("{ctx}: append failed oddly: {e}"),
                    }
                }
            }
            // FORK (10%)
            7 => {
                if let Some(&parent) = pick(&mut self.rng, &self.live) {
                    let plen = self.mgr.seq_len(parent).unwrap();
                    if plen == 0 {
                        return;
                    }
                    let at = 1 + self.rng.below(plen as u64) as usize;
                    let child = self.next_id;
                    match self.mgr.fork(parent, child, at) {
                        Ok(_) => {
                            self.next_id += 1;
                            self.live.push(child);
                        }
                        Err(AllocError::PoolExhausted { .. }) => {}
                        Err(e) => panic!("{ctx}: fork failed oddly: {e}"),
                    }
                }
            }
            // FREE (20%)
            _ => {
                if !self.live.is_empty() {
                    let i = self.rng.below(self.live.len() as u64) as usize;
                    let id = self.live.swap_remove(i);
                    self.mgr.free(id).unwrap();
                }
            }
        }
    }

    /// Check I1-I4.
    fn check(&self, ctx: &str) {
        let alloc = self.mgr.allocator();
        // gather per-page reference counts implied by tables
        let mut held: HashMap<u32, u32> = HashMap::new();
        for &id in &self.live {
            let t = self.mgr.table(id).unwrap();
            assert!(t.len_tokens() <= t.capacity_tokens(),
                    "{ctx}: I3 violated for seq {id}");
            assert!(t.n_blocks() <= MAX_BLOCKS, "{ctx}: block cap");
            for &p in t.pages() {
                *held.entry(p).or_insert(0) += 1;
            }
        }
        // I2: implied refs never exceed the allocator's refcount
        for (&p, &n) in &held {
            assert!(alloc.refcount(p) >= n,
                    "{ctx}: I2 page {p}: {n} holders > rc {}",
                    alloc.refcount(p));
        }
        // cached prefix pages are physically held by the index even
        // when no table references them (DESIGN.md §15)
        let mut physical = held.len();
        for p in self.mgr.cached_pages() {
            assert!(alloc.refcount(p) >= 1,
                    "{ctx}: cached page {p} is dead");
            if !held.contains_key(&p) {
                physical += 1;
            }
        }
        // I1: free + table-held + cache-only-held == capacity
        assert_eq!(alloc.free_pages() + physical, N_PAGES as usize,
                   "{ctx}: I1 conservation");
        // I4: reserved bytes track physically held pages
        let page_bytes = PAGE_SIZE as u64 * BYTES_PER_TOKEN;
        assert_eq!(alloc.audit().reserved_bytes(),
                   physical as u64 * page_bytes,
                   "{ctx}: I4 reserved-bytes accounting");
    }

    fn drain(&mut self, ctx: &str) {
        for id in std::mem::take(&mut self.live) {
            self.mgr.free(id).unwrap();
        }
        // registered prefixes outlive their owners by design; only a
        // cache flush lets I5 demand a fully free pool
        self.mgr.flush_prefix_cache();
        self.mgr.take_cache_evicted();
        let alloc = self.mgr.allocator();
        assert_eq!(alloc.free_pages(), N_PAGES as usize, "{ctx}: I5 free");
        assert_eq!(alloc.audit().reserved_bytes(), 0, "{ctx}: I5 reserved");
        assert_eq!(alloc.audit().live_bytes(), 0, "{ctx}: I5 live");
    }
}

fn pick<'a>(rng: &mut Rng, xs: &'a [u64]) -> Option<&'a u64> {
    if xs.is_empty() {
        None
    } else {
        Some(&xs[rng.below(xs.len() as u64) as usize])
    }
}

#[test]
fn random_interleavings_exact_policy() {
    for seed in 0..40u64 {
        let mut h = Harness::new(seed, GrowthPolicy::Exact);
        for step in 0..400 {
            let ctx = format!("seed {seed} step {step} (exact)");
            h.step(&ctx);
            h.check(&ctx);
        }
        h.drain(&format!("seed {seed} drain (exact)"));
    }
}

#[test]
fn random_interleavings_pow2_policy() {
    for seed in 100..130u64 {
        let mut h = Harness::new(seed, GrowthPolicy::PowerOfTwo);
        for step in 0..400 {
            let ctx = format!("seed {seed} step {step} (pow2)");
            h.step(&ctx);
            h.check(&ctx);
        }
        h.drain(&format!("seed {seed} drain (pow2)"));
    }
}

#[test]
fn exhaustion_recovery_cycles() {
    // fill the pool, free everything, repeat — byte accounting must not
    // drift across cycles.
    let mut h = Harness::new(77, GrowthPolicy::Exact);
    for cycle in 0..20 {
        let ctx = format!("cycle {cycle}");
        loop {
            let id = h.next_id;
            let prompt: Vec<u32> = (0..40).collect();
            match h.mgr.reserve(id, &prompt) {
                Ok(_) => {
                    h.next_id += 1;
                    h.live.push(id);
                    h.mgr.note_assigned(id, 40).unwrap();
                }
                Err(_) => break,
            }
        }
        assert!(h.mgr.allocator().free_pages() < 5, "{ctx}: pool filled");
        h.check(&ctx);
        h.drain(&ctx);
    }
}

// ----------------------------------------------------------------------
// Resident-window delta transfer vs full gather (DESIGN.md §5–6)
//
// Drives the kvpage + device-window layers the way engine::paged does —
// RESERVE/APPEND with host-side ASSIGN, fork CoW, FREE, preemption
// (invalidate), random device-buffer loss, batch-bucket flips, and
// per-step window gathers + device uploads — keeping one delta window
// and one full-gather window side by side, each backed by a pair of
// modeled device buffers (`DeviceWindow::sim`). After every gather and
// upload, each mapped page's window-resident contents AND its
// device-resident contents must be element-identical to the pool (and
// therefore to each other) on both paths: the dirty-range delta upload
// reconstructs exactly the device state the full re-upload produces.
// The window is sized once (fixed-W layout) so batch-size churn never
// relayouts it.
// ----------------------------------------------------------------------

const GEO: PoolGeometry = PoolGeometry {
    n_layers: 2,
    n_pages: N_PAGES as usize,
    page_size: PAGE_SIZE,
    n_kv_heads: 2,
    d_head: 4,
};
const BATCH_CAP: usize = 4;
const WINDOW_PAGES: usize = BATCH_CAP * MAX_BLOCKS;

struct WindowHarness {
    mgr: PageManager,
    k: HostPool,
    v: HostPool,
    delta: ResidentWindow,
    full: ResidentWindow,
    delta_kdev: DeviceWindow,
    delta_vdev: DeviceWindow,
    full_kdev: DeviceWindow,
    full_vdev: DeviceWindow,
    /// Randomly drop delta device buffers mid-run (exercises the
    /// full-upload fallback); off for the residency-survival test.
    inject_device_loss: bool,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
    counter: f32,
}

impl WindowHarness {
    fn new(seed: u64, policy: GrowthPolicy) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        let mut full = ResidentWindow::new(GEO);
        full.set_delta(false); // from-scratch gather every step
        WindowHarness {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            k: HostPool::zeros(GEO),
            v: HostPool::zeros(GEO),
            delta: ResidentWindow::new(GEO),
            full,
            delta_kdev: DeviceWindow::sim(),
            delta_vdev: DeviceWindow::sim(),
            full_kdev: DeviceWindow::sim(),
            full_vdev: DeviceWindow::sim(),
            inject_device_loss: true,
            live: vec![],
            next_id: 1,
            rng: Rng::seeded(seed),
            counter: 0.0,
        }
    }

    /// Host-side ASSIGN of positions [start, start+n) with fresh values
    /// (marks pages dirty, like the engine's scatter into the pool).
    fn write_tokens(&mut self, id: u64, start: usize, n: usize) {
        let pages = self.mgr.table(id).unwrap().pages().to_vec();
        for pos in start..start + n {
            let (page, off) = (pages[pos / PAGE_SIZE], pos % PAGE_SIZE);
            for layer in 0..GEO.n_layers {
                self.counter += 1.0;
                self.k.token_row_mut(layer, page, off)
                    .fill(self.counter);
                self.v.token_row_mut(layer, page, off)
                    .fill(-self.counter);
            }
        }
    }

    fn reserve_op(&mut self) {
        let id = self.next_id;
        let len = 1 + self.rng.below(60) as usize;
        let prompt: Vec<u32> =
            (0..len).map(|_| self.rng.below(512) as u32).collect();
        match self.mgr.reserve(id, &prompt) {
            Ok(out) => {
                self.next_id += 1;
                self.live.push(id);
                let fresh = prompt.len() - out.cached_tokens;
                self.write_tokens(id, out.cached_tokens, fresh);
                self.mgr.note_assigned(id, fresh).unwrap();
                if self.rng.below(2) == 0 {
                    self.mgr.register_prefix(id, &prompt).unwrap();
                }
            }
            Err(AllocError::PoolExhausted { .. })
            | Err(AllocError::CapacityExceeded { .. }) => {}
            Err(e) => panic!("reserve failed oddly: {e}"),
        }
    }

    fn append_op(&mut self) {
        let Some(&id) = pick(&mut self.rng, &self.live) else { return };
        let extra = 1 + self.rng.below(10) as usize;
        match self.mgr.prepare_append(id, extra) {
            Ok(plan) => {
                if let Some((src, dst)) = plan.cow_copy {
                    self.k.copy_page(src, dst);
                    self.v.copy_page(src, dst);
                }
                let len = self.mgr.seq_len(id).unwrap();
                self.write_tokens(id, len, extra);
                self.mgr.note_assigned(id, extra).unwrap();
            }
            Err(AllocError::PoolExhausted { .. })
            | Err(AllocError::CapacityExceeded { .. }) => {}
            Err(e) => panic!("append failed oddly: {e}"),
        }
    }

    fn fork_op(&mut self) {
        let Some(&parent) = pick(&mut self.rng, &self.live) else {
            return;
        };
        let plen = self.mgr.seq_len(parent).unwrap();
        if plen == 0 {
            return;
        }
        let at = 1 + self.rng.below(plen as u64) as usize;
        let child = self.next_id;
        match self.mgr.fork(parent, child, at) {
            Ok(plan) => {
                if let Some((src, dst)) = plan.cow_copy {
                    self.k.copy_page(src, dst);
                    self.v.copy_page(src, dst);
                }
                self.next_id += 1;
                self.live.push(child);
            }
            Err(AllocError::PoolExhausted { .. }) => {}
            Err(e) => panic!("fork failed oddly: {e}"),
        }
    }

    fn free_op(&mut self, preempt: bool) {
        if self.live.is_empty() {
            return;
        }
        let i = self.rng.below(self.live.len() as u64) as usize;
        let id = self.live.swap_remove(i);
        for page in self.mgr.free(id).unwrap() {
            self.delta.forget(page);
            self.full.forget(page);
        }
        if preempt {
            // exercise the wholesale invalidation fallback (explicit
            // invalidate / config toggle path; engine preemption itself
            // now just forgets dead pages like release)
            self.delta.invalidate();
        }
    }

    /// One engine-shaped decode step over a random batch: EXTEND + CoW,
    /// gather into both windows, upload to the device buffers, verify,
    /// then scatter the new token row with write-through into the delta
    /// window. The random batch size IS the bucket flip: under the
    /// fixed-W layout a changed batch never relayouts the window.
    fn decode_step_op(&mut self, ctx: &str) {
        let mut batch: Vec<u64> = vec![];
        let want = 1 + self.rng.below(BATCH_CAP as u64) as usize;
        for _ in 0..want {
            if let Some(&id) = pick(&mut self.rng, &self.live) {
                if !batch.contains(&id) {
                    batch.push(id);
                }
            }
        }
        if self.inject_device_loss {
            // occasional device-buffer loss, K and V independently:
            // the next apply must fall back to a full upload
            if self.rng.below(16) == 0 {
                self.delta_kdev.invalidate();
            }
            if self.rng.below(16) == 0 {
                self.delta_vdev.invalidate();
            }
        }
        self.decode_batch(&batch, ctx);
    }

    /// Decode step over an explicit batch (bucket-flip test drives this
    /// directly with a cycling batch size).
    fn decode_batch(&mut self, ids: &[u64], ctx: &str) {
        let mut batch: Vec<u64> = ids.to_vec();
        batch.retain(|&id| match self.mgr.prepare_append(id, 1) {
            Ok(plan) => {
                if let Some((src, dst)) = plan.cow_copy {
                    self.k.copy_page(src, dst);
                    self.v.copy_page(src, dst);
                }
                true
            }
            Err(AllocError::PoolExhausted { .. })
            | Err(AllocError::CapacityExceeded { .. }) => false,
            Err(e) => panic!("{ctx}: prepare_append: {e}"),
        });
        if batch.is_empty() {
            return;
        }

        // delta window maps first (it consumes the dirty bits)
        let mut mapped: Vec<(u64, Vec<u32>)> = vec![];
        self.delta.begin_step(WINDOW_PAGES);
        for &id in &batch {
            let len = self.mgr.seq_len(id).unwrap();
            let pages = self
                .mgr
                .table(id)
                .unwrap()
                .blocks_covering(len + 1)
                .to_vec();
            for &p in &pages {
                self.delta
                    .map_page(&mut self.k, &mut self.v, p)
                    .expect("delta window slots exhausted");
            }
            mapped.push((id, pages));
        }
        self.full.begin_step(WINDOW_PAGES);
        for (_, pages) in &mapped {
            for &p in pages {
                self.full
                    .map_page(&mut self.k, &mut self.v, p)
                    .expect("full window slots exhausted");
            }
        }

        // engine order: upload what changed (delta path) / everything
        // (full path) to the persistent device buffers, then verify.
        // Each buffer pair's own epoch drives its plan — a lost buffer
        // reads as epoch 0 and the plan goes Full by itself.
        let dev_epoch =
            self.delta_kdev.epoch().min(self.delta_vdev.epoch());
        let (plan, through) = self.delta.plan_for(dev_epoch, false);
        self.delta_kdev.apply_at(self.delta.k_window(), &plan, through);
        self.delta_vdev.apply_at(self.delta.v_window(), &plan, through);
        let fepoch =
            self.full_kdev.epoch().min(self.full_vdev.epoch());
        let (fplan, fthrough) = self.full.plan_for(fepoch, false);
        assert_eq!(fplan, UploadPlan::Full,
                   "{ctx}: full-gather window must order full uploads");
        self.full_kdev.apply_at(self.full.k_window(), &fplan, fthrough);
        self.full_vdev.apply_at(self.full.v_window(), &fplan, fthrough);
        self.verify(ctx, &mapped);

        // scatter one decoded token per sequence, write-through to the
        // resident delta window (the full window re-gathers anyway)
        for &id in &batch {
            let len = self.mgr.seq_len(id).unwrap();
            let pages = self.mgr.table(id).unwrap().pages().to_vec();
            let (page, off) =
                (pages[len / PAGE_SIZE], len % PAGE_SIZE);
            for layer in 0..GEO.n_layers {
                self.counter += 1.0;
                self.k.token_row_mut(layer, page, off)
                    .fill(self.counter);
                self.v.token_row_mut(layer, page, off)
                    .fill(-self.counter);
                self.delta.write_row(&mut self.k, &mut self.v, layer,
                                     page, off);
            }
            self.mgr.note_assigned(id, 1).unwrap();
        }
    }

    /// Every mapped page: delta window == full window == pool, AND
    /// delta device buffer == full device buffer == pool, for every
    /// layer, both pools — the dirty-range upload reconstructs exactly
    /// the device state a whole-window re-upload produces.
    fn verify(&self, ctx: &str, mapped: &[(u64, Vec<u32>)]) {
        let pe = GEO.page_elems();
        let dk = self.delta_kdev.contents()
            .expect("delta K device buffer resident after apply");
        let dv = self.delta_vdev.contents()
            .expect("delta V device buffer resident after apply");
        let fk = self.full_kdev.contents()
            .expect("full K device buffer resident after apply");
        let fv = self.full_vdev.contents()
            .expect("full V device buffer resident after apply");
        for (id, pages) in mapped {
            for &p in pages {
                let ds = self.delta.slot(p).unwrap();
                let fs = self.full.slot(p).unwrap();
                for layer in 0..GEO.n_layers {
                    let src = GEO.offset(layer, p, 0);
                    let kp = &self.k.as_slice()[src..src + pe];
                    let vp = &self.v.as_slice()[src..src + pe];
                    assert_eq!(self.delta.k_page_slice(layer, ds), kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: delta window diverged");
                    assert_eq!(self.full.k_page_slice(layer, fs), kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: full window diverged");
                    assert_eq!(self.delta.v_page_slice(layer, ds), vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: delta window diverged");
                    assert_eq!(self.full.v_page_slice(layer, fs), vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: full window diverged");
                    let doff =
                        (layer * WINDOW_PAGES + ds as usize) * pe;
                    let foff =
                        (layer * WINDOW_PAGES + fs as usize) * pe;
                    assert_eq!(&dk[doff..doff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: delta DEVICE diverged");
                    assert_eq!(&dv[doff..doff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: delta DEVICE diverged");
                    assert_eq!(&fk[foff..foff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: full DEVICE diverged");
                    assert_eq!(&fv[foff..foff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: full DEVICE diverged");
                }
            }
        }
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(10) {
            0..=2 => self.reserve_op(),
            3..=4 => self.append_op(),
            5 => self.fork_op(),
            6 => self.free_op(false),
            7 => self.free_op(true),
            _ => self.decode_step_op(ctx),
        }
        // cache surrender (LRU reclaim under pressure) kills pages
        // without a FREE — their window slots must be dropped exactly
        // like the free dead-list (DESIGN.md §15)
        for page in self.mgr.take_cache_evicted() {
            self.delta.forget(page);
            self.full.forget(page);
        }
    }
}

#[test]
fn window_delta_matches_full_gather_random_interleavings() {
    for seed in 0..12u64 {
        let policy = if seed % 2 == 0 {
            GrowthPolicy::Exact
        } else {
            GrowthPolicy::PowerOfTwo
        };
        let mut h = WindowHarness::new(1000 + seed, policy);
        for step in 0..250 {
            let ctx = format!("seed {seed} step {step} ({policy:?})");
            h.step(&ctx);
        }
        // drain: every sequence freed, cache flushed; pools fully
        // reclaimed
        while !h.live.is_empty() {
            h.free_op(false);
        }
        for page in h.mgr.flush_prefix_cache() {
            h.delta.forget(page);
            h.full.forget(page);
        }
        h.mgr.take_cache_evicted();
        assert_eq!(h.mgr.allocator().free_pages(), N_PAGES as usize,
                   "seed {seed}: pages leaked");
        assert!(h.delta.stats().full_gathers <= h.delta.stats().steps,
                "seed {seed}: gather accounting inconsistent");
        // the full-gather baseline always re-copies, so across a run it
        // must move at least as much as the delta path
        assert!(h.full.stats().bytes_moved
                    >= h.delta.stats().bytes_moved
                        - h.delta.stats().rows_written
                            * (2 * GEO.token_elems() * 4) as u64,
                "seed {seed}: delta gathered more page bytes than full");
        // same on the device half: whole-window re-uploads dominate
        // dirty-range pushes (even with injected buffer-loss fallbacks)
        assert!(h.delta_kdev.stats().bytes_uploaded
                    <= h.full_kdev.stats().bytes_uploaded,
                "seed {seed}: delta uploaded more than full re-upload");
    }
}

#[test]
fn fixed_window_survives_batch_bucket_flips() {
    // The fixed-W acceptance property: with W held constant, decode
    // batches of churning size (the engine's bucket flips) never
    // relayout the window — residency and the device buffers survive
    // the entire run with exactly one full gather and one full upload,
    // and every step's device contents stay element-identical to the
    // full-gather + full-upload baseline (checked inside decode_batch).
    let mut h = WindowHarness::new(4242, GrowthPolicy::Exact);
    h.inject_device_loss = false;
    for id in 1..=3u64 {
        let prompt: Vec<u32> =
            (0..20).map(|t| (id * 100 + t) as u32).collect();
        h.mgr.reserve(id, &prompt).unwrap();
        h.live.push(id);
        h.write_tokens(id, 0, prompt.len());
        h.mgr.note_assigned(id, prompt.len()).unwrap();
    }
    h.next_id = 4;

    // cycle through batch sizes 1 → 2 → 3 → 1 (decode-bucket flips),
    // with appends (chunked-prefill extensions) interleaved
    let batches: [&[u64]; 4] = [&[1], &[1, 2], &[1, 2, 3], &[2]];
    for step in 0..60usize {
        let ctx = format!("flip step {step}");
        if step % 5 == 4 {
            h.append_op();
        }
        h.decode_batch(batches[step % batches.len()], &ctx);
    }
    assert_eq!(h.delta.stats().full_gathers, 1,
               "bucket flips must not drop residency under fixed W");
    assert_eq!(h.delta_kdev.stats().full_uploads, 1,
               "bucket flips must not force device re-uploads");
    assert_eq!(h.delta_vdev.stats().full_uploads, 1);
    assert!(h.delta_kdev.stats().delta_uploads > 30,
            "steady steps must ride the dirty-range path");
}

#[test]
fn steady_single_sequence_decode_copies_o1_pages() {
    // The acceptance property: after the first gather, a steady-state
    // decode step copies at most one page per pool pair into the window
    // (the freshly mapped tail page at a page crossing; zero otherwise,
    // thanks to write-through), while a full gather re-copies every
    // live page every step.
    let mut h = WindowHarness::new(7, GrowthPolicy::Exact);
    let prompt: Vec<u32> = (0..40).collect(); // 5 pages
    h.mgr.reserve(1, &prompt).unwrap();
    h.live.push(1);
    h.write_tokens(1, 0, 40);
    h.mgr.note_assigned(1, 40).unwrap();

    let mut delta_total = 0u64;
    let mut full_total = 0u64;
    let steps = 24usize;
    for step in 0..steps {
        h.mgr.prepare_append(1, 1).unwrap();
        let len = h.mgr.seq_len(1).unwrap();

        h.delta.begin_step(WINDOW_PAGES);
        let pages =
            h.mgr.table(1).unwrap().blocks_covering(len + 1).to_vec();
        for &p in &pages {
            h.delta.map_page(&mut h.k, &mut h.v, p).unwrap();
        }
        h.full.begin_step(WINDOW_PAGES);
        for &p in &pages {
            h.full.map_page(&mut h.k, &mut h.v, p).unwrap();
        }
        if step > 0 {
            assert!(h.delta.stats().last_pages_copied <= 1,
                    "step {step}: delta copied {} pages",
                    h.delta.stats().last_pages_copied);
        }
        assert_eq!(h.full.stats().last_pages_copied, pages.len() as u64,
                   "step {step}: full gather must copy every live page");
        delta_total += h.delta.stats().last_pages_copied;
        full_total += h.full.stats().last_pages_copied;

        let (page, off) = (pages[len / PAGE_SIZE], len % PAGE_SIZE);
        for layer in 0..GEO.n_layers {
            h.counter += 1.0;
            h.k.token_row_mut(layer, page, off).fill(h.counter);
            h.v.token_row_mut(layer, page, off).fill(-h.counter);
            h.delta.write_row(&mut h.k, &mut h.v, layer, page, off);
        }
        h.mgr.note_assigned(1, 1).unwrap();
    }
    // step 0 full-gathers the 6 mapped pages; appending 24 tokens to a
    // 40-token sequence crosses a page boundary twice more (len 48, 56);
    // every other step rides the write-through and copies nothing
    assert!(delta_total <= 6 + 2,
            "delta moved {delta_total} pages over {steps} steps");
    assert!(full_total > delta_total * 10,
            "full gather ({full_total}) must dwarf delta \
             ({delta_total})");
}

// ----------------------------------------------------------------------
// Double-buffered transfer pipeline vs the serial dirty-range path
// (DESIGN.md §8)
//
// Two *independent* full replicas of the kvpage state machine (manager,
// pools, resident window) are driven through the same random op
// sequence: one uploads through the double-buffered TransferPipeline
// (epoch-tagged snapshots applied on the copy-stream worker thread,
// row tails, staged full refills; optionally a sharded deferred
// gather), the other through the serial single-pair plan_for path of
// PR 2. At every execute boundary, the pipeline's FRONT device
// contents and the serial device contents must both be
// element-identical to their pools for every mapped page — and
// therefore to each other (the replicas evolve identically). Random
// losses hit the pipeline's front/back halves and the serial buffers
// independently; preemption invalidates residency and drains the
// staged upload, exactly like the engine; a poisoned copy worker must
// demote staging inline without a single divergent byte.
// ----------------------------------------------------------------------

use paged_flex::engine::pipeline::TransferPipeline;

/// One full replica of the host-side decode state.
struct PathState {
    mgr: PageManager,
    k: HostPool,
    v: HostPool,
    win: ResidentWindow,
}

impl PathState {
    fn new(policy: GrowthPolicy) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        PathState {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            k: HostPool::zeros(GEO),
            v: HostPool::zeros(GEO),
            win: ResidentWindow::new(GEO),
        }
    }

    fn write_tokens(&mut self, id: u64, start: usize, n: usize,
                    counter: &mut f32) {
        let pages = self.mgr.table(id).unwrap().pages().to_vec();
        for pos in start..start + n {
            let (page, off) = (pages[pos / PAGE_SIZE], pos % PAGE_SIZE);
            for layer in 0..GEO.n_layers {
                *counter += 1.0;
                self.k.token_row_mut(layer, page, off).fill(*counter);
                self.v.token_row_mut(layer, page, off).fill(-*counter);
            }
        }
    }

    /// Every mapped page: window == pool (I6).
    fn assert_window_synced(&self, pages: &[u32], ctx: &str,
                            path: &str) {
        let pe = GEO.page_elems();
        for &p in pages {
            let slot = self.win.slot(p).unwrap();
            for layer in 0..GEO.n_layers {
                let src = GEO.offset(layer, p, 0);
                assert_eq!(self.win.k_page_slice(layer, slot),
                           &self.k.as_slice()[src..src + pe],
                           "{ctx}: {path} K page {p} layer {layer} \
                            window diverged");
                assert_eq!(self.win.v_page_slice(layer, slot),
                           &self.v.as_slice()[src..src + pe],
                           "{ctx}: {path} V page {p} layer {layer} \
                            window diverged");
            }
        }
    }
}

struct PipeHarness {
    /// Replica uploading through the double-buffered pipeline.
    p: PathState,
    pipe: TransferPipeline,
    /// Replica uploading through the serial PR 2 path.
    s: PathState,
    s_kdev: DeviceWindow,
    s_vdev: DeviceWindow,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
    counter_p: f32,
    counter_s: f32,
}

impl PipeHarness {
    /// `copy_threads` shards the PIPELINED replica's gather; the
    /// serial replica always runs the eager serial path, so the
    /// comparison also proves sharded == serial gather bytes.
    fn new(seed: u64, policy: GrowthPolicy, copy_threads: usize)
           -> Self {
        let mut p = PathState::new(policy);
        p.win.set_copy_threads(copy_threads);
        PipeHarness {
            p,
            pipe: TransferPipeline::sim(true),
            s: PathState::new(policy),
            s_kdev: DeviceWindow::sim(),
            s_vdev: DeviceWindow::sim(),
            live: vec![],
            next_id: 1,
            rng: Rng::seeded(seed),
            counter_p: 0.0,
            counter_s: 0.0,
        }
    }

    fn reserve_op(&mut self) {
        let id = self.next_id;
        let len = 1 + self.rng.below(60) as usize;
        let prompt: Vec<u32> =
            (0..len).map(|_| self.rng.below(512) as u32).collect();
        let a = self.p.mgr.reserve(id, &prompt);
        let b = self.s.mgr.reserve(id, &prompt);
        match (a, b) {
            (Ok(oa), Ok(ob)) => {
                assert_eq!(oa.cached_tokens, ob.cached_tokens,
                           "replicas diverged on admission");
                self.next_id += 1;
                self.live.push(id);
                let fresh = prompt.len() - oa.cached_tokens;
                self.p.write_tokens(id, oa.cached_tokens, fresh,
                                    &mut self.counter_p);
                self.s.write_tokens(id, ob.cached_tokens, fresh,
                                    &mut self.counter_s);
                self.p.mgr.note_assigned(id, fresh).unwrap();
                self.s.mgr.note_assigned(id, fresh).unwrap();
                if self.rng.below(2) == 0 {
                    self.p.mgr.register_prefix(id, &prompt).unwrap();
                    self.s.mgr.register_prefix(id, &prompt).unwrap();
                }
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on reserve outcome"),
        }
    }

    fn append_op(&mut self) {
        let Some(&id) = pick(&mut self.rng, &self.live) else { return };
        let extra = 1 + self.rng.below(10) as usize;
        let a = self.p.mgr.prepare_append(id, extra);
        let b = self.s.mgr.prepare_append(id, extra);
        match (a, b) {
            (Ok(pa), Ok(pb)) => {
                if let Some((src, dst)) = pa.cow_copy {
                    self.p.k.copy_page(src, dst);
                    self.p.v.copy_page(src, dst);
                }
                if let Some((src, dst)) = pb.cow_copy {
                    self.s.k.copy_page(src, dst);
                    self.s.v.copy_page(src, dst);
                }
                let len = self.p.mgr.seq_len(id).unwrap();
                self.p.write_tokens(id, len, extra,
                                    &mut self.counter_p);
                self.s.write_tokens(id, len, extra,
                                    &mut self.counter_s);
                self.p.mgr.note_assigned(id, extra).unwrap();
                self.s.mgr.note_assigned(id, extra).unwrap();
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on append outcome"),
        }
    }

    fn fork_op(&mut self) {
        let Some(&parent) = pick(&mut self.rng, &self.live) else {
            return;
        };
        let plen = self.p.mgr.seq_len(parent).unwrap();
        if plen == 0 {
            return;
        }
        let at = 1 + self.rng.below(plen as u64) as usize;
        let child = self.next_id;
        let a = self.p.mgr.fork(parent, child, at);
        let b = self.s.mgr.fork(parent, child, at);
        match (a, b) {
            (Ok(pa), Ok(pb)) => {
                if let Some((src, dst)) = pa.cow_copy {
                    self.p.k.copy_page(src, dst);
                    self.p.v.copy_page(src, dst);
                }
                if let Some((src, dst)) = pb.cow_copy {
                    self.s.k.copy_page(src, dst);
                    self.s.v.copy_page(src, dst);
                }
                self.next_id += 1;
                self.live.push(child);
                // PagedEngine::fork drains the staged upload, but a
                // manager-level fork does not — exercise BOTH
                // interleavings: the epoch protocol must keep the
                // undrained one correct too (invariant I8)
                if self.rng.below(2) == 0 {
                    self.pipe.drain();
                }
            }
            (Err(_), Err(_)) => {}
            _ => panic!("replicas diverged on fork outcome"),
        }
    }

    fn free_op(&mut self, preempt: bool) {
        if self.live.is_empty() {
            return;
        }
        let i = self.rng.below(self.live.len() as u64) as usize;
        let id = self.live.swap_remove(i);
        for page in self.p.mgr.free(id).unwrap() {
            self.p.win.forget(page);
        }
        for page in self.s.mgr.free(id).unwrap() {
            self.s.win.forget(page);
        }
        if preempt {
            // engine preemption: residency dropped, staged upload
            // drained (PagedEngine::preempt + the scheduler policy)
            self.p.win.invalidate();
            self.s.win.invalidate();
            self.pipe.drain();
        }
    }

    fn decode_step_op(&mut self, ctx: &str) {
        let mut batch: Vec<u64> = vec![];
        let want = 1 + self.rng.below(BATCH_CAP as u64) as usize;
        for _ in 0..want {
            if let Some(&id) = pick(&mut self.rng, &self.live) {
                if !batch.contains(&id) {
                    batch.push(id);
                }
            }
        }
        // independent loss injection: pipeline halves and serial
        // buffers each occasionally lose their device backing
        if self.rng.below(16) == 0 {
            self.pipe.front_mut().k.invalidate();
        }
        if self.rng.below(16) == 0 {
            self.pipe.back_mut().v.invalidate();
        }
        if self.rng.below(16) == 0 {
            self.s_kdev.invalidate();
        }

        // both replicas must agree on which ids can take a token
        batch.retain(|&id| {
            let a = self.p.mgr.prepare_append(id, 1);
            let b = self.s.mgr.prepare_append(id, 1);
            match (a, b) {
                (Ok(pa), Ok(pb)) => {
                    if let Some((src, dst)) = pa.cow_copy {
                        self.p.k.copy_page(src, dst);
                        self.p.v.copy_page(src, dst);
                    }
                    if let Some((src, dst)) = pb.cow_copy {
                        self.s.k.copy_page(src, dst);
                        self.s.v.copy_page(src, dst);
                    }
                    true
                }
                (Err(_), Err(_)) => false,
                _ => panic!("{ctx}: replicas diverged on append"),
            }
        });
        if batch.is_empty() {
            return;
        }

        // ---- pipelined replica: the engine's three stage boundaries
        self.pipe.begin_step(&mut self.p.win);
        self.p.win.begin_step(WINDOW_PAGES);
        let mut mapped: Vec<(u64, Vec<u32>)> = vec![];
        for &id in &batch {
            let len = self.p.mgr.seq_len(id).unwrap();
            let pages = self
                .p
                .mgr
                .table(id)
                .unwrap()
                .blocks_covering(len + 1)
                .to_vec();
            for &pg in &pages {
                self.p
                    .win
                    .map_page(&mut self.p.k, &mut self.p.v, pg)
                    .expect("pipeline window slots exhausted");
            }
            mapped.push((id, pages));
        }
        self.p.win.flush_pending(&self.p.k, &self.p.v);
        self.pipe.pre_execute(&mut self.p.win);

        // ---- serial replica: the PR 2 path
        self.s.win.begin_step(WINDOW_PAGES);
        for (_, pages) in &mapped {
            for &pg in pages {
                self.s
                    .win
                    .map_page(&mut self.s.k, &mut self.s.v, pg)
                    .expect("serial window slots exhausted");
            }
        }
        let (plan, through) = self.s.win.plan_for(
            self.s_kdev.epoch().min(self.s_vdev.epoch()),
            false,
        );
        self.s_kdev.apply_at(self.s.win.k_window(), &plan, through);
        self.s_vdev.apply_at(self.s.win.v_window(), &plan, through);

        self.verify(ctx, &mapped);
        self.pipe.note_execute(1_000_000);

        // scatter one token per sequence with write-through, both
        // replicas (identical values: counters advance in lockstep)
        for &id in &batch {
            let len = self.p.mgr.seq_len(id).unwrap();
            for (st, counter) in [
                (&mut self.p, &mut self.counter_p),
                (&mut self.s, &mut self.counter_s),
            ] {
                let pages = st.mgr.table(id).unwrap().pages().to_vec();
                let (page, off) =
                    (pages[len / PAGE_SIZE], len % PAGE_SIZE);
                for layer in 0..GEO.n_layers {
                    *counter += 1.0;
                    st.k.token_row_mut(layer, page, off).fill(*counter);
                    st.v.token_row_mut(layer, page, off)
                        .fill(-*counter);
                    st.win.write_row(&mut st.k, &mut st.v, layer, page,
                                     off);
                }
                st.mgr.note_assigned(id, 1).unwrap();
            }
        }
        // threaded ASSIGN mode defers the write-through memcpys; the
        // engine flushes at the end of its scatter, and so do we
        // (no-op at copy_threads 1 — the serial replica's path)
        self.p.win.flush_rows(&self.p.k, &self.p.v);
        self.s.win.flush_rows(&self.s.k, &self.s.v);
    }

    /// Execute-boundary equivalence: for every mapped page, the
    /// pipeline's FRONT device pair and the serial device pair are
    /// element-identical to their pools (and the replicas' pools are
    /// identical by construction) — an epoch handoff that uploaded a
    /// stale slot would surface here as a pool mismatch.
    fn verify(&self, ctx: &str, mapped: &[(u64, Vec<u32>)]) {
        let pe = GEO.page_elems();
        self.p.assert_window_synced(
            &mapped.iter().flat_map(|(_, p)| p.iter().copied())
                .collect::<Vec<_>>(),
            ctx, "pipeline");
        let fk = self.pipe.front().k.contents()
            .expect("pipeline front K resident after pre_execute");
        let fv = self.pipe.front().v.contents()
            .expect("pipeline front V resident after pre_execute");
        let sk = self.s_kdev.contents()
            .expect("serial K resident after apply");
        let sv = self.s_vdev.contents()
            .expect("serial V resident after apply");
        for (id, pages) in mapped {
            for &p in pages {
                let ps = self.p.win.slot(p).unwrap() as usize;
                let ss = self.s.win.slot(p).unwrap() as usize;
                for layer in 0..GEO.n_layers {
                    let src = GEO.offset(layer, p, 0);
                    let kp = &self.p.k.as_slice()[src..src + pe];
                    let vp = &self.p.v.as_slice()[src..src + pe];
                    let poff = (layer * WINDOW_PAGES + ps) * pe;
                    let soff = (layer * WINDOW_PAGES + ss) * pe;
                    assert_eq!(&fk[poff..poff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: pipeline FRONT device stale");
                    assert_eq!(&fv[poff..poff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: pipeline FRONT device stale");
                    assert_eq!(&sk[soff..soff + pe], kp,
                               "{ctx}: seq {id} K page {p} layer \
                                {layer}: serial device diverged");
                    assert_eq!(&sv[soff..soff + pe], vp,
                               "{ctx}: seq {id} V page {p} layer \
                                {layer}: serial device diverged");
                }
            }
        }
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(10) {
            0..=2 => self.reserve_op(),
            3 => self.append_op(),
            4 => self.fork_op(),
            5 => self.free_op(false),
            6 => self.free_op(true),
            _ => self.decode_step_op(ctx),
        }
        // both replicas evolve identically, so their caches surrender
        // the same pages; forget them like the free dead-list
        for page in self.p.mgr.take_cache_evicted() {
            self.p.win.forget(page);
        }
        for page in self.s.mgr.take_cache_evicted() {
            self.s.win.forget(page);
        }
    }
}

/// `PF_COPY_THREADS` override for the threaded suites (the CI
/// threaded-stress job sets 4).
fn env_copy_threads(default: usize) -> usize {
    std::env::var("PF_COPY_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

fn pipeline_matches_serial(seeds: std::ops::Range<u64>,
                           copy_threads: usize, steps: usize,
                           poison_at: Option<usize>) {
    for seed in seeds {
        let policy = if seed % 2 == 0 {
            GrowthPolicy::Exact
        } else {
            GrowthPolicy::PowerOfTwo
        };
        let mut h = PipeHarness::new(9000 + seed, policy, copy_threads);
        for step in 0..steps {
            if poison_at == Some(step) {
                // crash the transfer worker mid-run: the pipeline
                // must detect it, demote to inline staging, and keep
                // every subsequent verify green
                h.pipe.poison_stream_for_test();
            }
            let ctx = format!(
                "pipe seed {seed} step {step} ({policy:?}, \
                 threads {copy_threads})"
            );
            h.step(&ctx);
        }
        while !h.live.is_empty() {
            h.free_op(false);
        }
        for page in h.p.mgr.flush_prefix_cache() {
            h.p.win.forget(page);
        }
        for page in h.s.mgr.flush_prefix_cache() {
            h.s.win.forget(page);
        }
        assert_eq!(h.p.mgr.allocator().free_pages(), N_PAGES as usize,
                   "seed {seed}: pipeline replica leaked pages");
        assert_eq!(h.s.mgr.allocator().free_pages(), N_PAGES as usize,
                   "seed {seed}: serial replica leaked pages");
        let ps = h.pipe.stats();
        assert!(ps.staged_uploads > 0,
                "seed {seed}: pipeline never staged ({ps:?})");
        if poison_at.is_some() {
            assert!(ps.poisons >= 1,
                    "seed {seed}: injected poison never surfaced \
                     ({ps:?})");
        }
    }
}

#[test]
fn pipeline_matches_serial_upload_random_interleavings() {
    pipeline_matches_serial(0..10, 1, 250, None);
}

/// I8 in threaded mode: the pipelined replica's gather is deferred and
/// sharded across the scoped pool while the serial replica stays
/// eager — device states must remain element-identical.
#[test]
fn pipeline_matches_serial_upload_threaded_gather() {
    pipeline_matches_serial(20..26, env_copy_threads(4), 250, None);
}

/// I8 for the threaded ASSIGN scatter (PF_COPY_THREADS ≥ 2, floored
/// at 2 so the deferred path always engages): the pipelined replica's
/// write-through rows are queued and flushed sharded by
/// layer × slot-range while the serial replica scatters eagerly —
/// device states must remain element-identical, mirroring the PR 4
/// gather-shard equivalence test.
#[test]
fn pipeline_matches_serial_threaded_scatter() {
    pipeline_matches_serial(60..66, env_copy_threads(2).max(2), 250,
                            None);
}

/// Multi-iteration threaded stress: longer runs, sharded gather, and a
/// mid-run worker poison on every seed. Serving must survive the
/// crash (inline staging) with byte-identical device state throughout.
#[test]
fn threaded_pipeline_stress_survives_worker_poison() {
    pipeline_matches_serial(40..46, env_copy_threads(4), 400,
                            Some(120));
}

#[test]
fn epoch_handoff_never_uploads_a_stale_slot() {
    // Deterministic slot-reuse scenario: a page is mapped, staged into
    // the back pair, then freed; a NEW page steals its slot while the
    // old snapshot is still the back pair's last upload. At the next
    // execute boundary the front pair must show the new page's data —
    // the epoch tags force the reassigned slot back into a plan even
    // though the back pair already "has" that slot from the stale
    // snapshot.
    let mut h = PipeHarness::new(777, GrowthPolicy::Exact, 1);
    // sequence 1: one page worth of tokens
    let prompt: Vec<u32> = (0..PAGE_SIZE as u32 - 1).collect();
    h.p.mgr.reserve(1, &prompt).unwrap();
    h.s.mgr.reserve(1, &prompt).unwrap();
    h.live.push(1);
    h.p.write_tokens(1, 0, prompt.len(), &mut h.counter_p);
    h.s.write_tokens(1, 0, prompt.len(), &mut h.counter_s);
    h.p.mgr.note_assigned(1, prompt.len()).unwrap();
    h.s.mgr.note_assigned(1, prompt.len()).unwrap();
    h.next_id = 2;
    h.decode_step_op("warmup a");
    h.decode_step_op("warmup b");

    // free seq 1 (slot released), admit seq 2 over the same pages
    h.free_op(false);
    assert!(h.live.is_empty());
    let prompt2: Vec<u32> = (100..100 + PAGE_SIZE as u32).collect();
    h.p.mgr.reserve(2, &prompt2).unwrap();
    h.s.mgr.reserve(2, &prompt2).unwrap();
    h.live.push(2);
    h.p.write_tokens(2, 0, prompt2.len(), &mut h.counter_p);
    h.s.write_tokens(2, 0, prompt2.len(), &mut h.counter_s);
    h.p.mgr.note_assigned(2, prompt2.len()).unwrap();
    h.s.mgr.note_assigned(2, prompt2.len()).unwrap();

    // the next decode steps verify (inside decode_step_op) that the
    // front pair shows seq 2's rows, not seq 1's stale snapshot
    h.decode_step_op("reuse a");
    h.decode_step_op("reuse b");
    h.decode_step_op("reuse c");
}

// ----------------------------------------------------------------------
// I13: refcount + prefix-index + window-slot agreement (DESIGN.md §15)
//
// Random share / fork / unshare / preempt / quarantine interleavings
// over prompts drawn from a few shared base prefixes (so cache hits
// and radix sharing are common). After EVERY op:
//   * each page's refcount equals its table-holder count plus one if
//     the prefix index caches it — exactly, not just at least;
//   * no quarantined page is ever cached (quarantine atomically
//     un-shares the page and its radix descendants);
//   * free + referenced + quarantine-retired pages == capacity;
//   * every resident window slot maps a page with refcount > 0
//     (cache surrender and FREE both drop slots).
// PF_FAULT_SEED shifts the seed block (the CI chaos matrix reuses it).
// ----------------------------------------------------------------------

struct ShareHarness {
    mgr: PageManager,
    k: HostPool,
    v: HostPool,
    win: ResidentWindow,
    bases: Vec<Vec<u32>>,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
}

impl ShareHarness {
    fn new(seed: u64) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, GrowthPolicy::Exact));
        let mut rng = Rng::seeded(seed);
        let bases = (0..3)
            .map(|_| (0..40).map(|_| rng.below(512) as u32).collect())
            .collect();
        ShareHarness {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            k: HostPool::zeros(GEO),
            v: HostPool::zeros(GEO),
            win: ResidentWindow::new(GEO),
            bases,
            live: vec![],
            next_id: 1,
            rng,
        }
    }

    /// Shared base prefix cut at a random depth + a short random tail:
    /// hits, partial hits, and misses all occur.
    fn shared_prompt(&mut self) -> Vec<u32> {
        let b = &self.bases[self.rng.below(3) as usize];
        let cut = 8 + self.rng.below(33) as usize;
        let mut p = b[..cut.min(b.len())].to_vec();
        for _ in 0..self.rng.below(12) {
            p.push(self.rng.below(512) as u32);
        }
        p
    }

    fn free_seq(&mut self, id: u64) {
        for page in self.mgr.free(id).unwrap() {
            self.win.forget(page);
        }
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(12) {
            // RESERVE + always register: stir the radix index hard
            0..=3 => {
                let id = self.next_id;
                let prompt = self.shared_prompt();
                match self.mgr.reserve(id, &prompt) {
                    Ok(out) => {
                        self.next_id += 1;
                        self.live.push(id);
                        let fresh = prompt.len() - out.cached_tokens;
                        self.mgr.note_assigned(id, fresh).unwrap();
                        self.mgr.register_prefix(id, &prompt).unwrap();
                    }
                    Err(AllocError::PoolExhausted { .. })
                    | Err(AllocError::CapacityExceeded { .. }) => {}
                    Err(e) => panic!("{ctx}: reserve: {e}"),
                }
            }
            // APPEND (CoW breaks on shared tails)
            4..=5 => {
                if let Some(&id) = pick(&mut self.rng, &self.live) {
                    let extra = 1 + self.rng.below(8) as usize;
                    match self.mgr.prepare_append(id, extra) {
                        Ok(_) => {
                            self.mgr.note_assigned(id, extra).unwrap()
                        }
                        Err(AllocError::PoolExhausted { .. })
                        | Err(AllocError::CapacityExceeded { .. }) => {}
                        Err(e) => panic!("{ctx}: append: {e}"),
                    }
                }
            }
            // FAN-OUT: fork 1–3 children at a random point (the
            // manager half of PagedEngine::fork_n)
            6..=7 => {
                let Some(&parent) = pick(&mut self.rng, &self.live)
                else {
                    return;
                };
                let plen = self.mgr.seq_len(parent).unwrap();
                if plen == 0 {
                    return;
                }
                let at = 1 + self.rng.below(plen as u64) as usize;
                for _ in 0..1 + self.rng.below(3) {
                    let child = self.next_id;
                    match self.mgr.fork(parent, child, at) {
                        Ok(_) => {
                            self.next_id += 1;
                            self.live.push(child);
                        }
                        Err(AllocError::PoolExhausted { .. }) => break,
                        Err(e) => panic!("{ctx}: fork: {e}"),
                    }
                }
            }
            // QUARANTINE a random live page: atomic un-share
            8 => {
                if let Some(&id) = pick(&mut self.rng, &self.live) {
                    let pages =
                        self.mgr.table(id).unwrap().pages().to_vec();
                    if !pages.is_empty() {
                        let i = self.rng.below(pages.len() as u64);
                        self.mgr.quarantine_page(pages[i as usize]);
                    }
                }
            }
            // PREEMPT: wholesale residency invalidation
            9 => self.win.invalidate(),
            // MAP a live sequence's pages (decode-shaped residency)
            10 => {
                if let Some(&id) = pick(&mut self.rng, &self.live) {
                    self.win.begin_step(WINDOW_PAGES);
                    let pages =
                        self.mgr.table(id).unwrap().pages().to_vec();
                    for &p in &pages {
                        self.win
                            .map_page(&mut self.k, &mut self.v, p)
                            .expect("I13 window slots exhausted");
                    }
                }
            }
            // FREE
            _ => {
                if !self.live.is_empty() {
                    let i = self.rng.below(self.live.len() as u64);
                    let id = self.live.swap_remove(i as usize);
                    self.free_seq(id);
                }
            }
        }
        for page in self.mgr.take_cache_evicted() {
            self.win.forget(page);
        }
        self.check(ctx);
    }

    fn check(&self, ctx: &str) {
        let alloc = self.mgr.allocator();
        let mut holders: HashMap<u32, u32> = HashMap::new();
        for &id in &self.live {
            for &p in self.mgr.table(id).unwrap().pages() {
                *holders.entry(p).or_insert(0) += 1;
            }
        }
        let cached = self.mgr.cached_pages();
        for &p in &cached {
            assert!(!alloc.is_quarantined(p),
                    "{ctx}: I13 quarantined page {p} still cached");
        }
        let cached: std::collections::HashSet<u32> =
            cached.into_iter().collect();
        let mut phys = 0usize;
        for p in 0..N_PAGES {
            let rc = alloc.refcount(p);
            let want = holders.get(&p).copied().unwrap_or(0)
                + u32::from(cached.contains(&p));
            assert_eq!(rc, want,
                       "{ctx}: I13 page {p}: rc {rc} != holders + \
                        cached bit {want}");
            if rc > 0 {
                phys += 1;
            }
        }
        let retired = alloc
            .quarantined_pages()
            .iter()
            .filter(|&&p| alloc.refcount(p) == 0)
            .count();
        assert_eq!(alloc.free_pages() + phys + retired,
                   N_PAGES as usize, "{ctx}: I13 conservation");
        for p in self.win.resident_pages() {
            assert!(alloc.refcount(p) > 0,
                    "{ctx}: I13 window slot maps dead page {p}");
        }
    }
}

fn env_fault_seed() -> u64 {
    std::env::var("PF_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0)
}

#[test]
fn i13_share_fork_unshare_quarantine_interleavings() {
    let base = 5000 + env_fault_seed() * 131;
    for seed in base..base + 8 {
        let mut h = ShareHarness::new(seed);
        for step in 0..300 {
            let ctx = format!("I13 seed {seed} step {step}");
            h.step(&ctx);
        }
        while let Some(id) = h.live.pop() {
            h.free_seq(id);
        }
        for page in h.mgr.flush_prefix_cache() {
            h.win.forget(page);
        }
        h.mgr.take_cache_evicted();
        let alloc = h.mgr.allocator();
        let retired = alloc
            .quarantined_pages()
            .iter()
            .filter(|&&p| alloc.refcount(p) == 0)
            .count();
        assert_eq!(alloc.free_pages() + retired, N_PAGES as usize,
                   "I13 seed {seed}: drain left pages unaccounted");
        assert!(h.win.resident_pages().is_empty()
                    || h.win
                        .resident_pages()
                        .iter()
                        .all(|&p| alloc.refcount(p) > 0),
                "I13 seed {seed}: stale window residency after drain");
    }
}

#[test]
fn freelist_concurrent_with_manager_reads() {
    // The allocator must stay consistent when hammered from threads while
    // page counts are being read (the lock-free claim, Sec. II-B gap 3).
    let alloc = Arc::new(PageAllocator::new(
        256, 8, 16, GrowthPolicy::Exact));
    let mut handles = vec![];
    for t in 0..4u64 {
        let a = Arc::clone(&alloc);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(t);
            let mut held: Vec<Vec<u32>> = vec![];
            for _ in 0..5_000 {
                if rng.below(2) == 0 || held.is_empty() {
                    if let Some(pages) =
                        a.alloc_pages(1 + rng.below(4) as usize)
                    {
                        held.push(pages);
                    }
                } else {
                    let i = rng.below(held.len() as u64) as usize;
                    for p in held.swap_remove(i) {
                        a.release_page(p, 8);
                    }
                }
            }
            for pages in held {
                for p in pages {
                    a.release_page(p, 8);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(alloc.free_pages(), 256);
    assert_eq!(alloc.audit().reserved_bytes(), 0);
}
