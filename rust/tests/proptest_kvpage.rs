//! Randomized property tests over the page-manager state machine.
//!
//! No proptest crate offline, so this drives the invariants with an
//! in-tree PRNG across many seeds: thousands of random RESERVE / APPEND /
//! FORK / FREE interleavings, with full-state invariant checks after
//! every step. Failures print the seed + step for replay.
//!
//! Invariants (DESIGN.md §6):
//!  I1  page conservation: free + referenced-by-tables == capacity
//!  I2  no page appears in two tables unless its refcount covers it
//!  I3  every table's mapped capacity covers its live tokens
//!  I4  audit: reserved bytes == physically-held pages × page bytes
//!  I5  after all FREEs, the pool is fully free and audit is zero

use std::collections::HashMap;
use std::sync::Arc;

use paged_flex::kvpage::{
    AllocError, GrowthPolicy, PageAllocator, PageManager,
};
use paged_flex::trace::Rng;

const N_PAGES: u32 = 48;
const PAGE_SIZE: usize = 8;
const BYTES_PER_TOKEN: u64 = 16;
const MAX_BLOCKS: usize = 12;

struct Harness {
    mgr: PageManager,
    live: Vec<u64>,
    next_id: u64,
    rng: Rng,
}

impl Harness {
    fn new(seed: u64, policy: GrowthPolicy) -> Self {
        let alloc = Arc::new(PageAllocator::new(
            N_PAGES, PAGE_SIZE, BYTES_PER_TOKEN, policy));
        Harness {
            mgr: PageManager::new(alloc, MAX_BLOCKS),
            live: vec![],
            next_id: 1,
            rng: Rng::seeded(seed),
        }
    }

    fn random_prompt(&mut self) -> Vec<u32> {
        let len = 1 + self.rng.below(60) as usize;
        (0..len).map(|_| self.rng.below(512) as u32).collect()
    }

    fn step(&mut self, ctx: &str) {
        match self.rng.below(10) {
            // RESERVE (40%)
            0..=3 => {
                let id = self.next_id;
                let prompt = self.random_prompt();
                match self.mgr.reserve(id, &prompt) {
                    Ok(out) => {
                        self.next_id += 1;
                        self.live.push(id);
                        let fresh = prompt.len() - out.cached_tokens;
                        self.mgr.note_assigned(id, fresh).unwrap();
                        // register some prefixes to stir sharing
                        if self.rng.below(2) == 0 {
                            self.mgr.register_prefix(id, &prompt).unwrap();
                        }
                    }
                    Err(AllocError::PoolExhausted { .. })
                    | Err(AllocError::CapacityExceeded { .. }) => {}
                    Err(e) => panic!("{ctx}: reserve failed oddly: {e}"),
                }
            }
            // APPEND (30%)
            4..=6 => {
                if let Some(&id) = pick(&mut self.rng, &self.live) {
                    let extra = 1 + self.rng.below(12) as usize;
                    match self.mgr.prepare_append(id, extra) {
                        Ok(_) => self.mgr.note_assigned(id, extra).unwrap(),
                        Err(AllocError::PoolExhausted { .. })
                        | Err(AllocError::CapacityExceeded { .. }) => {}
                        Err(e) => panic!("{ctx}: append failed oddly: {e}"),
                    }
                }
            }
            // FORK (10%)
            7 => {
                if let Some(&parent) = pick(&mut self.rng, &self.live) {
                    let plen = self.mgr.seq_len(parent).unwrap();
                    if plen == 0 {
                        return;
                    }
                    let at = 1 + self.rng.below(plen as u64) as usize;
                    let child = self.next_id;
                    match self.mgr.fork(parent, child, at) {
                        Ok(_) => {
                            self.next_id += 1;
                            self.live.push(child);
                        }
                        Err(AllocError::PoolExhausted { .. }) => {}
                        Err(e) => panic!("{ctx}: fork failed oddly: {e}"),
                    }
                }
            }
            // FREE (20%)
            _ => {
                if !self.live.is_empty() {
                    let i = self.rng.below(self.live.len() as u64) as usize;
                    let id = self.live.swap_remove(i);
                    self.mgr.free(id).unwrap();
                }
            }
        }
    }

    /// Check I1-I4.
    fn check(&self, ctx: &str) {
        let alloc = self.mgr.allocator();
        // gather per-page reference counts implied by tables
        let mut held: HashMap<u32, u32> = HashMap::new();
        for &id in &self.live {
            let t = self.mgr.table(id).unwrap();
            assert!(t.len_tokens() <= t.capacity_tokens(),
                    "{ctx}: I3 violated for seq {id}");
            assert!(t.n_blocks() <= MAX_BLOCKS, "{ctx}: block cap");
            for &p in t.pages() {
                *held.entry(p).or_insert(0) += 1;
            }
        }
        // I2: implied refs never exceed the allocator's refcount
        for (&p, &n) in &held {
            assert!(alloc.refcount(p) >= n,
                    "{ctx}: I2 page {p}: {n} holders > rc {}",
                    alloc.refcount(p));
        }
        // I1: free + distinct-held == capacity
        assert_eq!(alloc.free_pages() + held.len(), N_PAGES as usize,
                   "{ctx}: I1 conservation");
        // I4: reserved bytes track physically held pages
        let page_bytes = PAGE_SIZE as u64 * BYTES_PER_TOKEN;
        assert_eq!(alloc.audit().reserved_bytes(),
                   held.len() as u64 * page_bytes,
                   "{ctx}: I4 reserved-bytes accounting");
    }

    fn drain(&mut self, ctx: &str) {
        for id in std::mem::take(&mut self.live) {
            self.mgr.free(id).unwrap();
        }
        let alloc = self.mgr.allocator();
        assert_eq!(alloc.free_pages(), N_PAGES as usize, "{ctx}: I5 free");
        assert_eq!(alloc.audit().reserved_bytes(), 0, "{ctx}: I5 reserved");
        assert_eq!(alloc.audit().live_bytes(), 0, "{ctx}: I5 live");
    }
}

fn pick<'a>(rng: &mut Rng, xs: &'a [u64]) -> Option<&'a u64> {
    if xs.is_empty() {
        None
    } else {
        Some(&xs[rng.below(xs.len() as u64) as usize])
    }
}

#[test]
fn random_interleavings_exact_policy() {
    for seed in 0..40u64 {
        let mut h = Harness::new(seed, GrowthPolicy::Exact);
        for step in 0..400 {
            let ctx = format!("seed {seed} step {step} (exact)");
            h.step(&ctx);
            h.check(&ctx);
        }
        h.drain(&format!("seed {seed} drain (exact)"));
    }
}

#[test]
fn random_interleavings_pow2_policy() {
    for seed in 100..130u64 {
        let mut h = Harness::new(seed, GrowthPolicy::PowerOfTwo);
        for step in 0..400 {
            let ctx = format!("seed {seed} step {step} (pow2)");
            h.step(&ctx);
            h.check(&ctx);
        }
        h.drain(&format!("seed {seed} drain (pow2)"));
    }
}

#[test]
fn exhaustion_recovery_cycles() {
    // fill the pool, free everything, repeat — byte accounting must not
    // drift across cycles.
    let mut h = Harness::new(77, GrowthPolicy::Exact);
    for cycle in 0..20 {
        let ctx = format!("cycle {cycle}");
        loop {
            let id = h.next_id;
            let prompt: Vec<u32> = (0..40).collect();
            match h.mgr.reserve(id, &prompt) {
                Ok(_) => {
                    h.next_id += 1;
                    h.live.push(id);
                    h.mgr.note_assigned(id, 40).unwrap();
                }
                Err(_) => break,
            }
        }
        assert!(h.mgr.allocator().free_pages() < 5, "{ctx}: pool filled");
        h.check(&ctx);
        h.drain(&ctx);
    }
}

#[test]
fn freelist_concurrent_with_manager_reads() {
    // The allocator must stay consistent when hammered from threads while
    // page counts are being read (the lock-free claim, Sec. II-B gap 3).
    let alloc = Arc::new(PageAllocator::new(
        256, 8, 16, GrowthPolicy::Exact));
    let mut handles = vec![];
    for t in 0..4u64 {
        let a = Arc::clone(&alloc);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(t);
            let mut held: Vec<Vec<u32>> = vec![];
            for _ in 0..5_000 {
                if rng.below(2) == 0 || held.is_empty() {
                    if let Some(pages) =
                        a.alloc_pages(1 + rng.below(4) as usize)
                    {
                        held.push(pages);
                    }
                } else {
                    let i = rng.below(held.len() as u64) as usize;
                    for p in held.swap_remove(i) {
                        a.release_page(p, 8);
                    }
                }
            }
            for pages in held {
                for p in pages {
                    a.release_page(p, 8);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(alloc.free_pages(), 256);
    assert_eq!(alloc.audit().reserved_bytes(), 0);
}
