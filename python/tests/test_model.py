"""L2 model: paged == contiguous == nocache numerical equivalence.

This is the paper's perplexity-equivalence claim (Sec. IV-B.3) at logits
level: the paged path must be bit-compatible (to fp tolerance) with the
dense baseline, for prefill, decode, chunked extension, and forks that
share pages.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS

RTOL = 3e-4
ATOL = 3e-4
CFG = CONFIGS["tiny"]


def scatter_chunk(kp, vp, k_chunk, v_chunk, bt, cache_lens, chunk_lens):
    """Host-side ASSIGN, mirroring kvpage::pool (the Rust engine's job)."""
    kp = np.asarray(kp).copy()
    vp = np.asarray(vp).copy()
    bt = np.asarray(bt)
    ps = CFG.page_size
    b = bt.shape[0]
    for i in range(b):
        for t in range(int(chunk_lens[i])):
            pos = int(cache_lens[i]) + t
            page, off = bt[i, pos // ps], pos % ps
            kp[:, page, off] = np.asarray(k_chunk)[:, i, :, t]
            vp[:, page, off] = np.asarray(v_chunk)[:, i, :, t]
    return jnp.asarray(kp), jnp.asarray(vp)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 42)


def fresh_pools():
    shape = (CFG.n_layers, CFG.n_pages, CFG.page_size, CFG.n_kv_heads,
             CFG.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def tables(rng, b):
    maxb = CFG.max_blocks_per_seq
    perm = rng.permutation(CFG.n_pages)[: b * maxb].reshape(b, maxb)
    return jnp.asarray(perm, jnp.int32)


def last_logits(params, tokens, lens):
    full = model.forward_logits(CFG, params, tokens, lens)
    return np.stack([np.asarray(full)[b, int(lens[b]) - 1]
                     for b in range(tokens.shape[0])])


class TestContiguous:
    def test_prefill_matches_full_logits(self, params):
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 24)),
                             jnp.int32)
        lens = jnp.asarray([24, 17], jnp.int32)
        lg, _, _ = model.forward_prefill(CFG, params, tokens, lens)
        np.testing.assert_allclose(lg, last_logits(params, tokens, lens),
                                   rtol=RTOL, atol=ATOL)

    def test_decode_chain_matches_full_forward(self, params):
        rng = np.random.default_rng(1)
        b, s0, steps = 2, 10, 6
        tokens = rng.integers(0, CFG.vocab_size, (b, s0 + steps)).astype(
            np.int32)
        lens0 = jnp.asarray([s0, s0 - 3], jnp.int32)
        _, kc, vc = model.forward_prefill(
            CFG, params, jnp.asarray(tokens[:, :s0]), lens0)
        lens = np.asarray(lens0).copy()
        for t in range(steps):
            nxt = jnp.asarray([tokens[i, lens[i]] for i in range(b)],
                              jnp.int32)
            lg, k_new, v_new = model.forward_decode(
                CFG, params, nxt, kc, vc, jnp.asarray(lens))
            # Rust-side cache write-back at position lens[i]
            kc_np, vc_np = np.asarray(kc).copy(), np.asarray(vc).copy()
            for i in range(b):
                kc_np[:, i, :, lens[i]] = np.asarray(k_new)[:, i]
                vc_np[:, i, :, lens[i]] = np.asarray(v_new)[:, i]
            kc, vc = jnp.asarray(kc_np), jnp.asarray(vc_np)
            lens += 1
            padded = np.zeros((b, s0 + steps), np.int32)
            for i in range(b):
                padded[i, : lens[i]] = tokens[i, : lens[i]]
            exp = last_logits(params, jnp.asarray(padded),
                              jnp.asarray(lens))
            np.testing.assert_allclose(lg, exp, rtol=RTOL, atol=ATOL)


class TestPaged:
    def test_cold_prefill_matches_contiguous(self, params):
        rng = np.random.default_rng(2)
        tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 24)),
                             jnp.int32)
        lens = jnp.asarray([24, 17], jnp.int32)
        kp, vp = fresh_pools()
        bt = tables(rng, 2)
        lg, _, _ = model.forward_paged(
            CFG, params, tokens, kp, vp, bt, jnp.zeros(2, jnp.int32), lens)
        # (chunk KV returned; pools untouched by the executable)
        np.testing.assert_allclose(lg, last_logits(params, tokens, lens),
                                   rtol=RTOL, atol=ATOL)

    def test_decode_chain_matches_contiguous(self, params):
        rng = np.random.default_rng(3)
        b, s0, steps = 2, 16, 5
        tokens = rng.integers(0, CFG.vocab_size, (b, s0 + steps)).astype(
            np.int32)
        lens = np.asarray([s0, s0 - 5], np.int32)
        kp, vp = fresh_pools()
        bt = tables(rng, b)
        _, kc, vc = model.forward_paged(
            CFG, params, jnp.asarray(tokens[:, :s0]), kp, vp, bt,
            jnp.zeros(b, jnp.int32), jnp.asarray(lens))
        kp, vp = scatter_chunk(kp, vp, kc, vc, bt,
                               np.zeros(b, np.int32), lens)
        for t in range(steps):
            nxt = jnp.asarray([[tokens[i, lens[i]]] for i in range(b)],
                              jnp.int32)
            lg, kc, vc = model.forward_paged(
                CFG, params, nxt, kp, vp, bt, jnp.asarray(lens),
                jnp.ones(b, jnp.int32))
            kp, vp = scatter_chunk(kp, vp, kc, vc, bt, lens,
                                   np.ones(b, np.int32))
            lens += 1
            padded = np.zeros((b, s0 + steps), np.int32)
            for i in range(b):
                padded[i, : lens[i]] = tokens[i, : lens[i]]
            exp = last_logits(params, jnp.asarray(padded),
                              jnp.asarray(lens))
            np.testing.assert_allclose(lg, exp, rtol=RTOL, atol=ATOL)

    def test_chunked_extension_matches_one_shot(self, params):
        rng = np.random.default_rng(4)
        full = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, 48)),
                           jnp.int32)
        bt = tables(rng, 1)
        # one shot
        kp, vp = fresh_pools()
        lg_one, _, _ = model.forward_paged(
            CFG, params, full, kp, vp, bt, jnp.zeros(1, jnp.int32),
            jnp.asarray([48], jnp.int32))
        # two chunks of 24 (chat growth)
        kp, vp = fresh_pools()
        _, kc, vc = model.forward_paged(
            CFG, params, full[:, :24], kp, vp, bt,
            jnp.zeros(1, jnp.int32), jnp.asarray([24], jnp.int32))
        kp, vp = scatter_chunk(kp, vp, kc, vc, bt, [0], [24])
        lg_two, _, _ = model.forward_paged(
            CFG, params, full[:, 24:], kp, vp, bt,
            jnp.asarray([24], jnp.int32), jnp.asarray([24], jnp.int32))
        np.testing.assert_allclose(lg_two, lg_one, rtol=RTOL, atol=ATOL)

    def test_prefix_sharing_pages(self, params):
        # Two sequences share prefix pages (same physical pages in both
        # tables); decoding each must equal decoding without sharing.
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, CFG.vocab_size, (1, 16)).astype(np.int32)
        kp, vp = fresh_pools()
        bt0 = jnp.asarray([[0, 1, 50, 51] + [0] * 12], jnp.int32)
        _, kc, vc = model.forward_paged(
            CFG, params, jnp.asarray(prefix), kp, vp, bt0,
            jnp.zeros(1, jnp.int32), jnp.asarray([16], jnp.int32))
        kp, vp = scatter_chunk(kp, vp, kc, vc, bt0, [0], [16])
        # fork: second table aliases pages 0,1 then diverges to 60,61
        bt = jnp.asarray([[0, 1, 50, 51] + [0] * 12,
                          [0, 1, 60, 61] + [0] * 12], jnp.int32)
        nxt = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 1)),
                          jnp.int32)
        lg, _, _ = model.forward_paged(
            CFG, params, nxt, kp, vp, bt,
            jnp.asarray([16, 16], jnp.int32), jnp.ones(2, jnp.int32))
        # both forks see the identical prefix -> same-token forks agree
        if int(nxt[0, 0]) == int(nxt[1, 0]):
            np.testing.assert_allclose(lg[0], lg[1], rtol=RTOL, atol=ATOL)
        # and each matches an unshared run
        for i in range(2):
            lg_i, _, _ = model.forward_paged(
                CFG, params, nxt[i:i + 1], kp, vp, bt[i:i + 1],
                jnp.asarray([16], jnp.int32), jnp.ones(1, jnp.int32))
            np.testing.assert_allclose(lg[i], lg_i[0], rtol=RTOL,
                                       atol=ATOL)


class TestPoolService:
    def test_copy_read_write_roundtrip(self, params):
        rng = np.random.default_rng(6)
        kp, vp = fresh_pools()
        vals = jnp.asarray(
            rng.normal(size=(CFG.n_layers, CFG.max_blocks_per_seq,
                             CFG.page_size, CFG.n_kv_heads, CFG.d_head)),
            jnp.float32)
        idx = jnp.asarray(
            list(range(3)) + [CFG.n_pages] * (CFG.max_blocks_per_seq - 3),
            jnp.int32)  # 3 live, rest dropped
        kp, vp = model.write_pages(CFG, kp, vp, idx, vals, vals)
        k_out, v_out = model.read_pages(CFG, kp, vp, idx)
        np.testing.assert_allclose(k_out[:, :3], vals[:, :3], rtol=0,
                                   atol=0)
        # copy page 1 -> 10 and check
        src = jnp.asarray([1] + [CFG.n_pages] * (CFG.max_blocks_per_seq - 1),
                          jnp.int32)
        dst = jnp.asarray([10] + [CFG.n_pages] * (CFG.max_blocks_per_seq - 1),
                          jnp.int32)
        kp, vp = model.copy_pages(CFG, kp, vp, src, dst)
        k_out, _ = model.read_pages(CFG, kp, vp, dst)
        np.testing.assert_allclose(k_out[:, 0], vals[:, 1], rtol=0, atol=0)

    def test_nocache_matches(self, params):
        rng = np.random.default_rng(7)
        tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 20)),
                             jnp.int32)
        lens = jnp.asarray([20, 11], jnp.int32)
        lg = model.forward_nocache(CFG, params, tokens, lens)
        np.testing.assert_allclose(lg, last_logits(params, tokens, lens),
                                   rtol=RTOL, atol=ATOL)
