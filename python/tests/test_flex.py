"""flex.flex_attention vs ref.ref_flex_attention — the FlexAttention engine.

Covers: every mask mod, every score mod, GQA head ratios, non-divisible
(padded) sequence lengths, q_offset (decode/chunk positioning), BlockMask
soundness (dense and coarse builders agree with unpruned execution), lse
output, and a hypothesis sweep over shapes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flex, mods, ref

RTOL = 2e-5
ATOL = 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def make_qkv(rng, b=2, h=4, hkv=2, sq=48, skv=48, d=16):
    return (rand(rng, b, h, sq, d), rand(rng, b, hkv, skv, d),
            rand(rng, b, hkv, skv, d))


def check(q, k, v, mask_mod=None, score_mod=None, **kw):
    out = flex.flex_attention(q, k, v, mask_mod, score_mod, **kw)
    exp = ref.ref_flex_attention(q, k, v, mask_mod, score_mod,
                                 q_offset=kw.get("q_offset", 0))
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


class TestMaskMods:
    def setup_method(self):
        self.rng = np.random.default_rng(42)

    def test_no_mask(self):
        check(*make_qkv(self.rng))

    def test_causal(self):
        check(*make_qkv(self.rng), mask_mod=mods.causal)

    def test_full_equals_no_mask(self):
        q, k, v = make_qkv(self.rng)
        a = flex.flex_attention(q, k, v, mods.full)
        b = flex.flex_attention(q, k, v, None)
        np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("window", [1, 7, 16, 100])
    def test_sliding_window(self, window):
        check(*make_qkv(self.rng), mask_mod=mods.sliding_window(window))

    @pytest.mark.parametrize("prefix", [0, 5, 48])
    def test_prefix_lm(self, prefix):
        check(*make_qkv(self.rng), mask_mod=mods.prefix_lm(prefix))

    def test_padded_causal(self):
        q, k, v = make_qkv(self.rng, b=3)
        seq_lens = jnp.asarray([5, 48, 17])
        check(q, k, v, mask_mod=mods.padded_causal(seq_lens))

    def test_document(self):
        q, k, v = make_qkv(self.rng, b=1, sq=40, skv=40)
        doc_ids = jnp.asarray([0] * 11 + [1] * 9 + [2] * 20)
        check(q, k, v, mask_mod=mods.document(doc_ids))

    def test_sequence_local_jagged(self):
        # The paper's own mask (Sec. III-B): 3 sequences packed into 40
        # slots, live lengths shorter than their packed extents.
        q, k, v = make_qkv(self.rng, b=1, sq=40, skv=40)
        seq_ids = jnp.asarray([0] * 16 + [1] * 8 + [2] * 16)
        seq_lens = jnp.asarray([12, 8, 13])
        check(q, k, v, mask_mod=mods.sequence_local(seq_ids, seq_lens))

    def test_and_or_combinators(self):
        q, k, v = make_qkv(self.rng)
        m = mods.and_masks(mods.causal, mods.sliding_window(9))
        check(q, k, v, mask_mod=m)
        m = mods.or_masks(mods.sliding_window(3), mods.prefix_lm(4))
        check(q, k, v, mask_mod=m)

    def test_fully_masked_rows_are_finite(self):
        # Rows that attend to nothing must come out zero/finite, never NaN.
        q, k, v = make_qkv(self.rng, b=2)
        seq_lens = jnp.asarray([0, 5])
        out = flex.flex_attention(q, k, v, mods.padded_causal(seq_lens))
        assert np.isfinite(np.asarray(out)).all()


class TestScoreMods:
    def setup_method(self):
        self.rng = np.random.default_rng(7)

    def test_alibi(self):
        q, k, v = make_qkv(self.rng)
        check(q, k, v, mask_mod=mods.causal, score_mod=mods.alibi(4))

    @pytest.mark.parametrize("cap", [1.0, 5.0, 50.0])
    def test_soft_cap(self, cap):
        check(*make_qkv(self.rng), mask_mod=mods.causal,
              score_mod=mods.soft_cap(cap))

    def test_relative_bias(self):
        q, k, v = make_qkv(self.rng)
        table = rand(np.random.default_rng(0), 4, 8)
        check(q, k, v, mask_mod=mods.causal,
              score_mod=mods.relative_bias(table))

    def test_compose(self):
        sm = mods.compose_scores(mods.alibi(4), mods.soft_cap(10.0))
        check(*make_qkv(self.rng), mask_mod=mods.causal, score_mod=sm)


class TestShapesAndGQA:
    def setup_method(self):
        self.rng = np.random.default_rng(3)

    @pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1), (6, 3)])
    def test_gqa_ratios(self, h, hkv):
        check(*make_qkv(self.rng, h=h, hkv=hkv), mask_mod=mods.causal)

    @pytest.mark.parametrize("sq,skv", [(1, 64), (33, 65), (5, 5),
                                        (64, 1), (100, 37)])
    def test_ragged_padding(self, sq, skv):
        # Non-multiples of block sizes exercise the padding/validity path.
        check(*make_qkv(self.rng, sq=sq, skv=skv), mask_mod=None)

    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 64), (64, 16)])
    def test_block_shape_invariance(self, bq, bk):
        q, k, v = make_qkv(self.rng, sq=70, skv=70)
        check(q, k, v, mask_mod=mods.causal, block_q=bq, block_k=bk)

    def test_q_offset_decode_semantics(self):
        # One query positioned at the end of a 30-token context must equal
        # the last row of full causal attention.
        q, k, v = make_qkv(self.rng, sq=30, skv=30)
        full = flex.flex_attention(q, k, v, mods.causal)
        one = flex.flex_attention(q[:, :, -1:], k, v, mods.causal,
                                  q_offset=29)
        np.testing.assert_allclose(one[:, :, 0], full[:, :, -1],
                                   rtol=RTOL, atol=ATOL)

    def test_return_lse(self):
        q, k, v = make_qkv(self.rng, sq=16, skv=16)
        out, lse = flex.flex_attention(q, k, v, mods.causal,
                                       return_lse=True)
        # lse must reproduce the dense logsumexp of masked scaled scores.
        scale = 1.0 / np.sqrt(q.shape[-1])
        kf = ref.repeat_kv(k, 2)
        s = np.einsum("bhqd,bhkd->bhqk", q, kf) * scale
        qi = np.arange(16)[:, None]
        ki = np.arange(16)[None, :]
        s = np.where(ki <= qi, s, ref.NEG_INF)
        exp_lse = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) + \
            s.max(-1)
        np.testing.assert_allclose(lse, exp_lse, rtol=1e-4, atol=1e-4)


class TestBlockMask:
    def setup_method(self):
        self.rng = np.random.default_rng(11)

    def test_dense_builder_prunes_correctly(self):
        q, k, v = make_qkv(self.rng, sq=64, skv=64)
        bm = flex.create_block_mask(mods.causal, 2, 4, 64, 64, 16, 16)
        pruned = flex.flex_attention(q, k, v, mods.causal, block_mask=bm,
                                     block_q=16, block_k=16)
        unpruned = flex.flex_attention(q, k, v, mods.causal,
                                       block_q=16, block_k=16)
        np.testing.assert_allclose(pruned, unpruned, rtol=RTOL, atol=ATOL)

    def test_dense_builder_structure(self):
        bm = np.asarray(flex.create_block_mask(mods.causal, 1, 1, 64, 64,
                                               16, 16))[0, 0]
        # strictly upper-triangular blocks are dead, diagonal+lower live
        for i in range(4):
            for j in range(4):
                assert bm[i, j] == (1 if j <= i else 0)

    @pytest.mark.parametrize("mod_name", ["causal", "window", "padded"])
    def test_coarse_matches_dense_for_monotone_mods(self, mod_name):
        mod = {"causal": mods.causal,
               "window": mods.sliding_window(10),
               "padded": mods.padded_causal(jnp.asarray([7, 33]))}[mod_name]
        dense = flex.create_block_mask(mod, 2, 2, 48, 48, 16, 16)
        coarse = flex.create_block_mask_coarse(mod, 2, 2, 48, 48, 16, 16)
        # coarse may only over-approximate (superset of live blocks)...
        assert (np.asarray(coarse) >= np.asarray(dense)).all()
        # ...and for these monotone mods it is exact.
        np.testing.assert_array_equal(np.asarray(coarse),
                                      np.asarray(dense))

    def test_sparsity_saves_blocks(self):
        bm = np.asarray(flex.create_block_mask(
            mods.sliding_window(16), 1, 1, 256, 256, 16, 16))
        assert bm.mean() < 0.3  # window mask kills most blocks


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h_pair=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4)]),
    sq=st.integers(1, 70),
    skv=st.integers(1, 70),
    d=st.sampled_from([4, 16, 32]),
    causal=st.booleans(),
)
def test_hypothesis_sweep(b, h_pair, sq, skv, d, causal):
    h, hkv = h_pair
    rng = np.random.default_rng(b * 1000 + sq * 10 + skv)
    q = rand(rng, b, h, sq, d)
    k = rand(rng, b, hkv, skv, d)
    v = rand(rng, b, hkv, skv, d)
    mod = mods.causal if causal else None
    if causal and skv < sq:
        return  # causal over shorter kv leaves q rows fully masked: sep test
    check(q, k, v, mask_mod=mod)
