"""Paged decode / chunked prefill kernels vs their dense oracles.

Exercises the block-table indirection (Alg. 1 GATHER fused in-kernel):
scattered/permuted/reused pages, partial last pages, GQA, page-size sweep,
zero cache, and a hypothesis sweep over pool geometry.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import paged_attention as pa
from compile.kernels import paged_prefill as pp
from compile.kernels import ref

RTOL = 2e-5
ATOL = 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def make_pool(rng, n_pages=32, page=8, hkv=2, d=16):
    return (rand(rng, n_pages, page, hkv, d),
            rand(rng, n_pages, page, hkv, d))


def scatter_tables(rng, b, max_blocks, n_pages):
    """Distinct pages per sequence, deliberately scattered over the pool."""
    perm = rng.permutation(n_pages)
    assert b * max_blocks <= n_pages
    return jnp.asarray(perm[: b * max_blocks].reshape(b, max_blocks),
                       jnp.int32)


class TestPagedDecode:
    def setup_method(self):
        self.rng = np.random.default_rng(5)

    def _run(self, seq_lens, b=3, h=4, hkv=2, d=16, page=8, n_pages=32,
             max_blocks=8):
        kp, vp = make_pool(self.rng, n_pages, page, hkv, d)
        bt = scatter_tables(self.rng, b, max_blocks, n_pages)
        q = rand(self.rng, b, h, d)
        sl = jnp.asarray(seq_lens, jnp.int32)
        out = pa.paged_decode_attention(q, kp, vp, bt, sl)
        exp = ref.ref_paged_decode(q, kp, vp, bt, sl, page)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_basic(self):
        self._run([5, 23, 64])

    def test_single_token_context(self):
        self._run([1, 1, 1])

    def test_exact_page_boundaries(self):
        self._run([8, 16, 64])

    def test_one_off_boundaries(self):
        self._run([7, 9, 63])

    @pytest.mark.parametrize("page", [1, 2, 8, 16])
    def test_page_size_sweep(self, page):
        kp, vp = make_pool(self.rng, 64, page, 2, 16)
        bt = scatter_tables(self.rng, 2, 16, 64)
        q = rand(self.rng, 2, 4, 16)
        sl = jnp.asarray([3, 16 * page - 1], jnp.int32)
        out = pa.paged_decode_attention(q, kp, vp, bt, sl)
        exp = ref.ref_paged_decode(q, kp, vp, bt, sl, page)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (8, 1)])
    def test_gqa(self, h, hkv):
        self._run([10, 30, 50], h=h, hkv=hkv)

    def test_shared_pages_between_sequences(self):
        # Prefix sharing: two sequences point at the SAME physical pages.
        kp, vp = make_pool(self.rng)
        shared = jnp.asarray([[3, 9, 1, 0], [3, 9, 2, 0]], jnp.int32)
        q = rand(self.rng, 2, 4, 16)
        sl = jnp.asarray([16, 24], jnp.int32)  # first 2 pages shared
        out = pa.paged_decode_attention(q, kp, vp, shared, sl)
        exp = ref.ref_paged_decode(q, kp, vp, shared, sl, 8)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_garbage_tail_entries_ignored(self):
        # Table entries past the live range must not affect the result.
        kp, vp = make_pool(self.rng)
        q = rand(self.rng, 1, 4, 16)
        sl = jnp.asarray([10], jnp.int32)
        bt_a = jnp.asarray([[4, 7, 0, 0]], jnp.int32)
        bt_b = jnp.asarray([[4, 7, 31, 13]], jnp.int32)
        out_a = pa.paged_decode_attention(q, kp, vp, bt_a, sl)
        out_b = pa.paged_decode_attention(q, kp, vp, bt_b, sl)
        np.testing.assert_allclose(out_a, out_b, rtol=0, atol=0)

    def test_matches_contiguous_attention(self):
        # Paged result == dense attention over the linearized sequence.
        kp, vp = make_pool(self.rng)
        bt = scatter_tables(self.rng, 1, 4, 32)
        length = 27
        q = rand(self.rng, 1, 4, 16)
        sl = jnp.asarray([length], jnp.int32)
        ks = ref.gather_pages(kp, bt[0], length, 8).transpose(1, 0, 2)[None]
        vs = ref.gather_pages(vp, bt[0], length, 8).transpose(1, 0, 2)[None]
        dense = ref.ref_attention(q[:, :, None], ks, vs)[:, :, 0]
        out = pa.paged_decode_attention(q, kp, vp, bt, sl)
        np.testing.assert_allclose(out, dense, rtol=RTOL, atol=ATOL)


class TestPagedPrefill:
    def setup_method(self):
        self.rng = np.random.default_rng(9)

    def _run(self, cache_lens, c=40, b=3, h=4, hkv=2, d=16, page=8,
             n_pages=32, max_blocks=8, block_q=32):
        kp, vp = make_pool(self.rng, n_pages, page, hkv, d)
        bt = scatter_tables(self.rng, b, max_blocks, n_pages)
        qc = rand(self.rng, b, h, c, d)
        kc = rand(self.rng, b, hkv, c, d)
        vc = rand(self.rng, b, hkv, c, d)
        cl = jnp.asarray(cache_lens, jnp.int32)
        out = pp.paged_prefill_attention(qc, kc, vc, kp, vp, bt, cl,
                                         block_q=block_q)
        exp = ref.ref_paged_prefill(qc, kc, vc, kp, vp, bt, cl, page)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_cold_start(self):
        # cache_len = 0 everywhere: pure causal prefill.
        self._run([0, 0, 0])

    def test_warm_extension(self):
        self._run([13, 60, 8])

    def test_page_aligned_cache(self):
        self._run([8, 16, 32])

    @pytest.mark.parametrize("c", [1, 7, 32, 65])
    def test_chunk_sizes(self, c):
        self._run([5, 20, 0], c=c)

    @pytest.mark.parametrize("block_q", [8, 16, 64])
    def test_block_q_invariance(self, block_q):
        self._run([13, 60, 8], block_q=block_q)

    def test_gqa(self):
        self._run([10, 3, 40], h=8, hkv=2)

    def test_chunked_equals_one_shot(self):
        # Prefill of 32 tokens in two 16-token chunks == one 32-token chunk.
        kp = jnp.zeros((8, 8, 2, 16), jnp.float32)
        vp = jnp.zeros((8, 8, 2, 16), jnp.float32)
        bt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
        q = rand(self.rng, 1, 4, 32, 16)
        k = rand(self.rng, 1, 2, 32, 16)
        v = rand(self.rng, 1, 2, 32, 16)
        one = pp.paged_prefill_attention(
            q, k, v, kp, vp, bt, jnp.asarray([0], jnp.int32))
        # chunk 1 writes its K/V into pages 0..1 (ASSIGN done densely here)
        kp2 = kp.at[jnp.asarray([0, 1])].set(
            k[0, :, :16].transpose(1, 0, 2).reshape(2, 8, 2, 16))
        vp2 = vp.at[jnp.asarray([0, 1])].set(
            v[0, :, :16].transpose(1, 0, 2).reshape(2, 8, 2, 16))
        first = pp.paged_prefill_attention(
            q[:, :, :16], k[:, :, :16], v[:, :, :16], kp, vp, bt,
            jnp.asarray([0], jnp.int32))
        second = pp.paged_prefill_attention(
            q[:, :, 16:], k[:, :, 16:], v[:, :, 16:], kp2, vp2, bt,
            jnp.asarray([16], jnp.int32))
        chunked = jnp.concatenate([first, second], axis=2)
        np.testing.assert_allclose(chunked, one, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    page=st.sampled_from([2, 4, 8]),
    max_blocks=st.integers(1, 6),
    frac=st.floats(0.05, 1.0),
    h_pair=st.sampled_from([(2, 2), (4, 2)]),
)
def test_hypothesis_decode_sweep(b, page, max_blocks, frac, h_pair):
    h, hkv = h_pair
    rng = np.random.default_rng(b * 100 + page * 10 + max_blocks)
    n_pages = b * max_blocks + 4
    kp = rand(rng, n_pages, page, hkv, 8)
    vp = rand(rng, n_pages, page, hkv, 8)
    bt = scatter_tables(rng, b, max_blocks, n_pages)
    cap = page * max_blocks
    sl = jnp.asarray([max(1, int(frac * cap))] * b, jnp.int32)
    q = rand(rng, b, h, 8)
    out = pa.paged_decode_attention(q, kp, vp, bt, sl)
    exp = ref.ref_paged_decode(q, kp, vp, bt, sl, page)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)
