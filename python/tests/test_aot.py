"""AOT pipeline invariants: manifest schema, weight round-trip, HLO headers.

Runs against the real artifacts/ directory when present (created by
`make artifacts`); the manifest-structure tests synthesize a tiny export
into a temp dir otherwise, so the suite works in a fresh checkout too.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model, weights
from compile.configs import AOT_PLAN, CONFIGS, paged_window_pages

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))


class TestWeights:
    def test_roundtrip(self, tmp_path):
        cfg = CONFIGS["tiny"]
        params = model.init_params(cfg, 7)
        path = str(tmp_path / "w.bin")
        entries, sha = weights.save_weights(cfg, params, path)
        assert len(sha) == 64
        loaded = weights.load_weights(cfg, path)
        for name, _ in model.param_spec(cfg):
            np.testing.assert_array_equal(np.asarray(params[name]),
                                          loaded[name])

    def test_entries_are_contiguous(self, tmp_path):
        cfg = CONFIGS["tiny"]
        params = model.init_params(cfg, 7)
        entries, _ = weights.save_weights(cfg, params,
                                          str(tmp_path / "w.bin"))
        offset = 0
        for e in entries:
            assert e["offset"] == offset
            assert e["bytes"] == int(np.prod(e["shape"])) * 4
            offset += e["bytes"]

    def test_deterministic_init(self):
        cfg = CONFIGS["tiny"]
        a = model.init_params(cfg, 42)
        b = model.init_params(cfg, 42)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))

    def test_param_count_matches_spec(self):
        for cfg in CONFIGS.values():
            total = sum(int(np.prod(s)) for _, s in model.param_spec(cfg))
            assert total == cfg.param_count(), cfg.name


class TestPlanCoverage:
    def test_every_config_has_a_plan(self):
        assert set(AOT_PLAN) == set(CONFIGS)

    def test_paged_decode_batches_covered_by_chunk_prefill(self):
        # Every decode batch size needs a prefill path able to feed it.
        for name, plan in AOT_PLAN.items():
            chunk_batches = {b for b, _ in plan["paged_chunk"]}
            for b in plan["paged_decode"]:
                assert any(cb <= b for cb in chunk_batches), (name, b)

    def test_buckets_fit_model_limits(self):
        for name, plan in AOT_PLAN.items():
            cfg = CONFIGS[name]
            for _, s in plan["prefill"]:
                assert s <= cfg.max_seq_len
            for _, c in plan["paged_chunk"]:
                assert c <= cfg.pooled_tokens


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_version_and_configs(self, manifest):
        assert manifest["version"] == aot.MANIFEST_VERSION
        for name in manifest["configs"]:
            assert name in CONFIGS

    def test_model_dict_matches_config(self, manifest):
        for name, entry in manifest["configs"].items():
            cfg = CONFIGS[name]
            md = entry["model"]
            assert md["d_model"] == cfg.d_model
            assert md["page_size"] == cfg.page_size
            assert md["n_pages"] == cfg.n_pages
            assert md["kv_bytes_per_token"] == cfg.kv_bytes_per_token

    def test_artifact_files_exist_with_alias_headers(self, manifest):
        for name, entry in manifest["configs"].items():
            for aname, art in entry["artifacts"].items():
                path = os.path.join(ART, art["file"])
                assert os.path.exists(path), path
                with open(path) as f:
                    head = f.readline()
                assert head.startswith("HloModule"), path
                if art["donated_inputs"]:
                    assert "input_output_alias" in head, (
                        f"{path}: donation lost in lowering")

    def test_weight_files_match_manifest_size(self, manifest):
        for name, entry in manifest["configs"].items():
            path = os.path.join(ART, entry["weights_file"])
            expect = sum(p["bytes"] for p in entry["params"])
            assert os.path.getsize(path) == expect

    def test_pool_shapes_consistent(self, manifest):
        for name, entry in manifest["configs"].items():
            cfg = CONFIGS[name]
            # layout must be consistent per config: every paged
            # artifact fixed-W (default, DESIGN.md §6) or every one
            # per-bucket (--window-layout per_bucket export) — a mixed
            # manifest means a partially stale export. For the largest
            # bucket the two sizes coincide, which is consistent with
            # either layout.
            fixed_w = paged_window_pages(name)
            layouts = set()
            for aname, art in entry["artifacts"].items():
                service = art["kind"] in ("copy_pages", "read_pages",
                                          "write_pages")
                pb_w = art.get("batch", 1) * cfg.max_blocks_per_seq
                for inp in art["inputs"]:
                    if inp["name"] not in ("k_pool", "v_pool"):
                        continue
                    pages = inp["shape"][1]
                    tail = [cfg.page_size, cfg.n_kv_heads, cfg.d_head]
                    assert inp["shape"] == [cfg.n_layers, pages] + tail, \
                        (aname, inp)
                    if service:
                        assert pages == cfg.n_pages, (aname, inp)
                        continue
                    assert pages in (fixed_w, pb_w), (aname, inp)
                    if pages == fixed_w != pb_w:
                        layouts.add("fixed")
                    elif pages == pb_w != fixed_w:
                        layouts.add("per_bucket")
            assert len(layouts) <= 1, (
                f"{name}: mixed window layouts {layouts} — "
                "partially stale export, re-run compile.aot --force")

    def test_fixed_window_covers_every_bucket(self):
        for name, plan in AOT_PLAN.items():
            cfg = CONFIGS[name]
            w = paged_window_pages(name)
            for b in plan["paged_decode"]:
                assert w >= b * cfg.max_blocks_per_seq, (name, b)
            for b, _ in plan["paged_chunk"]:
                assert w >= b * cfg.max_blocks_per_seq, (name, b)
