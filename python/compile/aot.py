"""AOT pipeline: lower every planned executable to HLO *text* + manifest.

Interchange format is HLO text, NOT `XlaComputation.serialize()`: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a jax.jit lowering of one `model.forward_*` entry point
with the flat parameter list as leading arguments. KV pools / caches are
donated (`donate_argnums`), which survives to `input_output_alias` in the
HLO text and lets PJRT update them in place — Alg. 1's ASSIGN without a
copy of the pool.

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--configs tiny,bench,small] [--force]

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import AOT_PLAN, CONFIGS, ModelConfig, paged_window_pages
from .weights import save_weights

WEIGHT_SEED = 42
MANIFEST_VERSION = 1

I32 = jnp.int32
F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_specs(cfg: ModelConfig):
    return [_spec(shape) for _, shape in model.param_spec(cfg)]


def _pool_shape(cfg: ModelConfig, n_pages=None):
    """Pool tensor shape. Paged model artifacts use the *active subpool*
    window, sized ONCE per config (fixed W = max_blocks_per_seq × the
    largest paged batch bucket, `configs.paged_window_pages`): the
    runtime gathers the pages referenced by the step's block tables into
    this dense window and remaps table entries, so per-step transfer
    scales with the active set, not pool capacity — and because every
    paged bucket shares the same W, the runtime's resident window and
    device buffer survive bucket changes (DESIGN.md §5–6).
    Pool-service artifacts keep the full cfg.n_pages shape."""
    if n_pages is None:
        n_pages = cfg.n_pages
    return (cfg.n_layers, n_pages, cfg.page_size, cfg.n_kv_heads,
            cfg.d_head)


def _cache_shape(cfg: ModelConfig, b: int):
    return (cfg.n_layers, b, cfg.n_kv_heads, cfg.max_seq_len, cfg.d_head)


def _wrap(cfg, entry, n_params):
    """Bind cfg and re-split the flat AOT argument list."""

    def fn(*args):
        params = model.params_from_list(cfg, args[:n_params])
        return entry(cfg, params, *args[n_params:])

    return fn


def build_artifacts(cfg: ModelConfig, per_bucket_window: bool = False):
    """Yield (name, kind, meta, fn, input_specs, donate_indices, takes_params).

    donate indices are relative to the full flat arg list; manifest input
    indices are relative to the post-params tail. `per_bucket_window`
    restores the pre-fixed-W shape (W = b × max_blocks_per_seq per
    bucket) for deployments on full-upload-only backends that prefer
    small windows over bucket-stable residency (pair with the runtime's
    `window_layout = per_bucket`).
    """
    n = len(model.param_spec(cfg))
    plan = AOT_PLAN[cfg.name]

    for b, s in plan["prefill"]:
        yield (
            f"prefill_b{b}_s{s}", "prefill", {"batch": b, "seq": s},
            _wrap(cfg, model.forward_prefill, n),
            [("tokens", _spec((b, s), I32)), ("seq_lens", _spec((b,), I32))],
            (), True,
        )
    for b in plan["decode"]:
        yield (
            f"decode_b{b}", "decode", {"batch": b},
            _wrap(cfg, model.forward_decode, n),
            [("tokens", _spec((b,), I32)),
             ("k_cache", _spec(_cache_shape(cfg, b))),
             ("v_cache", _spec(_cache_shape(cfg, b))),
             ("seq_lens", _spec((b,), I32))],
            (), True,  # cache write-back is Rust-side
        )
    fixed_pages = paged_window_pages(cfg.name)
    window_pages = lambda b: (b * cfg.max_blocks_per_seq
                              if per_bucket_window else fixed_pages)
    paged_inputs = lambda b, c: [
        ("tokens", _spec((b, c), I32)),
        ("k_pool", _spec(_pool_shape(cfg, window_pages(b)))),
        ("v_pool", _spec(_pool_shape(cfg, window_pages(b)))),
        ("block_tables", _spec((b, cfg.max_blocks_per_seq), I32)),
        ("cache_lens", _spec((b,), I32)),
        ("chunk_lens", _spec((b,), I32)),
    ]
    for b in plan["paged_decode"]:
        yield (
            f"decode_paged_b{b}", "paged_decode", {"batch": b, "chunk": 1},
            _wrap(cfg, model.forward_paged, n),
            paged_inputs(b, 1),
            (), True,  # pools are inputs only; ASSIGN is Rust-side
        )
    for b, c in plan["paged_chunk"]:
        yield (
            f"paged_chunk_b{b}_c{c}", "paged_chunk", {"batch": b, "chunk": c},
            _wrap(cfg, model.forward_paged, n),
            paged_inputs(b, c),
            (), True,
        )
    for s in plan["nocache"]:
        yield (
            f"nocache_s{s}", "nocache", {"batch": 1, "seq": s},
            _wrap(cfg, model.forward_nocache, n),
            [("tokens", _spec((1, s), I32)), ("seq_lens", _spec((1,), I32))],
            (), True,
        )
    for s in plan["logits"]:
        yield (
            f"logits_s{s}", "logits", {"batch": 1, "seq": s},
            _wrap(cfg, model.forward_logits, n),
            [("tokens", _spec((1, s), I32)), ("seq_lens", _spec((1,), I32))],
            (), True,
        )

    # pool-service executables: no model params, pools donated
    pool = _spec(_pool_shape(cfg))
    nb = cfg.max_blocks_per_seq
    page_block = _spec((cfg.n_layers, nb, cfg.page_size, cfg.n_kv_heads,
                        cfg.d_head))
    yield (
        "copy_pages", "copy_pages", {},
        functools.partial(model.copy_pages, cfg),
        [("k_pool", pool), ("v_pool", pool),
         ("src", _spec((nb,), I32)), ("dst", _spec((nb,), I32))],
        (0, 1), False,
    )
    yield (
        "read_pages", "read_pages", {},
        functools.partial(model.read_pages, cfg),
        [("k_pool", pool), ("v_pool", pool), ("idx", _spec((nb,), I32))],
        (), False,
    )
    yield (
        "write_pages", "write_pages", {},
        functools.partial(model.write_pages, cfg),
        [("k_pool", pool), ("v_pool", pool), ("idx", _spec((nb,), I32)),
         ("k_vals", page_block), ("v_vals", page_block)],
        (0, 1), False,
    )


def lower_artifact(fn, param_specs, input_specs, donate):
    lowered = jax.jit(fn, donate_argnums=donate).lower(
        *param_specs, *[s for _, s in input_specs])
    out_tree = lowered.out_info
    out_shapes = [
        {"shape": list(o.shape), "dtype": str(o.dtype)}
        for o in jax.tree_util.tree_leaves(out_tree)
    ]
    return to_hlo_text(lowered), out_shapes


def export_config(cfg: ModelConfig, out_dir: str, force: bool,
                  per_bucket_window: bool = False) -> dict:
    os.makedirs(os.path.join(out_dir, cfg.name), exist_ok=True)
    params = model.init_params(cfg, WEIGHT_SEED)
    weights_file = f"weights_{cfg.name}.bin"
    weights_path = os.path.join(out_dir, weights_file)
    entries, sha = save_weights(cfg, params, weights_path)
    print(f"[{cfg.name}] weights: {weights_file} "
          f"({cfg.param_count() / 1e6:.1f}M params, sha {sha[:12]})")
    del params

    param_specs = _param_specs(cfg)
    n_params = len(param_specs)
    artifacts = {}
    # Input shapes recorded by the previous export, if any: an existing
    # .hlo.txt is only reusable when its input contract is unchanged
    # (the fixed-W window resize is exactly such a contract change).
    prior = {}
    prior_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(prior_path):
        with open(prior_path) as f:
            prior = (json.load(f).get("configs", {})
                     .get(cfg.name, {}).get("artifacts", {}))
    for (name, kind, meta, fn, input_specs, donate,
         takes_params) in build_artifacts(cfg, per_bucket_window):
        rel = os.path.join(cfg.name, f"{name}.hlo.txt")
        path = os.path.join(out_dir, rel)
        a_params = param_specs if takes_params else []
        a_n = len(a_params)
        record = {
            "file": rel,
            "kind": kind,
            **meta,
            "takes_params": takes_params,
            "inputs": [
                {"name": iname, "shape": list(s.shape), "dtype": str(s.dtype)}
                for iname, s in input_specs
            ],
            "donated_inputs": [d - a_n for d in donate],
        }
        unchanged = (prior.get(name, {}).get("inputs")
                     == record["inputs"])
        if os.path.exists(path) and not force and unchanged:
            # Source staleness is the caller's concern; shape staleness
            # is checked here (a reused .hlo.txt with a changed input
            # contract would pass manifest validation but fail at
            # execute). Output shapes re-derive from a cheap abstract
            # eval.
            t0 = time.time()
            _, out_shapes = lower_artifact(fn, a_params, input_specs,
                                           donate)
            record["outputs"] = out_shapes
            artifacts[name] = record
            print(f"[{cfg.name}] {name}: exists, kept "
                  f"({time.time() - t0:.1f}s)")
            continue
        t0 = time.time()
        text, out_shapes = lower_artifact(fn, a_params, input_specs,
                                          donate)
        with open(path + ".tmp", "w") as f:
            f.write(text)
        os.replace(path + ".tmp", path)
        record["outputs"] = out_shapes
        artifacts[name] = record
        print(f"[{cfg.name}] {name}: {len(text) / 1e6:.2f} MB HLO "
              f"({time.time() - t0:.1f}s)")
    return {
        "model": cfg.to_dict(),
        "weights_file": weights_file,
        "weights_sha256": sha,
        "n_params": n_params,
        "params": entries,
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,bench,small")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--window-layout", choices=["fixed", "per_bucket"],
        default="fixed",
        help="paged KV window sizing: 'fixed' (one W per config, "
             "residency survives bucket changes) or 'per_bucket' "
             "(W = b × max_blocks_per_seq, smaller uploads on "
             "full-upload-only backends; pair with the runtime's "
             "window_layout = per_bucket)")
    args = ap.parse_args()

    per_bucket = args.window_layout == "per_bucket"
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": MANIFEST_VERSION, "configs": {}}
    t0 = time.time()
    for name in args.configs.split(","):
        cfg = CONFIGS[name.strip()]
        manifest["configs"][cfg.name] = export_config(
            cfg, args.out, args.force, per_bucket)
    man_path = os.path.join(args.out, "manifest.json")
    with open(man_path + ".tmp", "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(man_path + ".tmp", man_path)
    print(f"manifest: {man_path} ({time.time() - t0:.0f}s total)")


if __name__ == "__main__":
    main()
