"""Weight export: flat little-endian f32 binary + manifest entries.

The binary is the concatenation of every parameter in `model.param_spec`
order (the same order the AOT executables take them as leading arguments).
Rust (`runtime::weights`) mmap-reads the file and slices it by the manifest
offsets — no pickle, no framework formats on the request path.
"""

from __future__ import annotations

import hashlib
import os
from typing import Dict, List, Tuple

import numpy as np

from .configs import ModelConfig
from .model import Params, param_spec


def save_weights(cfg: ModelConfig, params: Params, path: str
                 ) -> Tuple[List[dict], str]:
    """Write the flat binary; return (manifest entries, sha256 hex)."""
    entries = []
    offset = 0
    hasher = hashlib.sha256()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for name, shape in param_spec(cfg):
            arr = np.asarray(params[name], dtype=np.float32)
            assert arr.shape == tuple(shape), (name, arr.shape, shape)
            raw = arr.tobytes()  # C-order little-endian f32
            f.write(raw)
            hasher.update(raw)
            entries.append({
                "name": name,
                "shape": list(shape),
                "offset": offset,
                "bytes": len(raw),
            })
            offset += len(raw)
    os.replace(tmp, path)
    return entries, hasher.hexdigest()


def load_weights(cfg: ModelConfig, path: str) -> Dict[str, np.ndarray]:
    """Inverse of save_weights (used by tests for round-trip checks)."""
    params = {}
    with open(path, "rb") as f:
        raw = f.read()
    offset = 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape)) * 4
        params[name] = np.frombuffer(
            raw[offset:offset + n], dtype=np.float32).reshape(shape)
        offset += n
    assert offset == len(raw), "weight file size mismatch"
    return params
