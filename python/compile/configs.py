"""Model-size registry and AOT bucket plan.

Mirrored by Rust `model::ModelSpec` (rust/src/model/mod.rs): the two must
agree on every field — the manifest written by `aot.py` is the contract, and
Rust validates its copy against it at load time.

Scale substitution (DESIGN.md §1): the paper runs LLaMA-7B; we keep the
exact architecture (RMSNorm, RoPE, SwiGLU, GQA-capable MHA) at CPU-feasible
sizes. KV-cache geometry — the quantity every experiment in the paper
actually measures — is preserved structurally: bytes/token =
n_layers * n_kv_heads * d_head * 4 B * 2.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq_len: int      # M: contiguous-cache capacity AND paged max ctx
    page_size: int        # tokens per KV page (paper Sec. III-B: 64-128 on
    #                       GPU; 16 here = one (16,128)-friendly TPU tile)
    n_pages: int          # P: pool capacity in pages (per layer)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def max_blocks_per_seq(self) -> int:
        return self.max_seq_len // self.page_size

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 K+V bytes per token across all layers."""
        return self.n_layers * self.n_kv_heads * self.d_head * 4 * 2

    @property
    def pooled_tokens(self) -> int:
        return self.n_pages * self.page_size

    def param_count(self) -> int:
        d, dh, ff, v = self.d_model, self.d_head, self.d_ff, self.vocab_size
        per_layer = (
            d * self.n_heads * dh          # wq
            + 2 * d * self.n_kv_heads * dh  # wk, wv
            + self.n_heads * dh * d        # wo
            + 3 * d * ff                   # gate, up, down
            + 2 * d                        # two norms
        )
        return self.n_layers * per_layer + 2 * v * d + d

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["d_head"] = self.d_head
        out["max_blocks_per_seq"] = self.max_blocks_per_seq
        out["kv_bytes_per_token"] = self.kv_bytes_per_token
        out["param_count"] = self.param_count()
        return out


CONFIGS: Dict[str, ModelConfig] = {
    # tests / CI: seconds-fast end to end
    "tiny": ModelConfig(
        name="tiny", vocab_size=512, d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, d_ff=176, max_seq_len=128, page_size=8, n_pages=64),
    # benchmark harness: the paper's 128..2048 sweeps at CPU-feasible cost
    "bench": ModelConfig(
        name="bench", vocab_size=512, d_model=256, n_layers=4, n_heads=4,
        n_kv_heads=2, d_ff=704, max_seq_len=2048, page_size=16,
        n_pages=512),
    # e2e serving example: ~18M params, LLaMA-7B geometry scaled down
    "small": ModelConfig(
        name="small", vocab_size=512, d_model=512, n_layers=6, n_heads=8,
        n_kv_heads=4, d_ff=1408, max_seq_len=2048, page_size=16,
        n_pages=512),
}


# AOT bucket plan: which executables `aot.py` lowers per config.
#   prefill      (B, S)  contiguous-cache prefill
#   decode       B       contiguous-cache decode step
#   paged_decode B       paged decode step (chunk == 1 fast path)
#   paged_chunk  (B, C)  paged prefill/extension chunk (cache_lens == 0 is
#                        cold-start prefill; > 0 is chat-growth extension)
#   nocache      S       full-recompute forward (Fig 3 baseline)
#   logits       S       full-sequence logits (perplexity)
AotPlan = Dict[str, List]

AOT_PLAN: Dict[str, AotPlan] = {
    "tiny": dict(
        prefill=[(2, 64)],
        decode=[2],
        paged_decode=[2],
        paged_chunk=[(1, 32), (2, 64)],
        nocache=[64],
        logits=[64],
    ),
    "bench": dict(
        prefill=[(1, 128), (1, 512), (1, 2048)],
        decode=[1, 4],
        paged_decode=[1, 4, 8, 16],
        paged_chunk=[(1, 128), (1, 512), (1, 1024), (1, 2048), (4, 512),
                     (8, 512), (16, 512)],
        nocache=[128, 256, 512, 1024, 2048],
        logits=[512],
    ),
    "small": dict(
        prefill=[(1, 512), (4, 512)],
        decode=[1, 4],
        paged_decode=[1, 2, 4, 8],
        paged_chunk=[(1, 512), (2, 512), (4, 512), (8, 512), (1, 2048)],
        nocache=[],
        logits=[512],
    ),
}


def prefill_buckets(name: str) -> List[Tuple[int, int]]:
    return AOT_PLAN[name]["prefill"]


def paged_window_pages(name: str) -> int:
    """Fixed resident-window size W shared by every paged artifact of a
    config: max_blocks_per_seq × the largest paged batch bucket
    (decode and chunk plans together). Because W no longer depends on
    the bucket, the runtime's resident window and device buffer survive
    batch-size churn and prefill/decode alternation (DESIGN.md §6); the
    Rust side validates this invariant from the manifest
    (`ConfigEntry::paged_window_pages`)."""
    plan = AOT_PLAN[name]
    cfg = CONFIGS[name]
    batches = [b for b in plan["paged_decode"]]
    batches += [b for b, _ in plan["paged_chunk"]]
    return cfg.max_blocks_per_seq * max(batches, default=1)
