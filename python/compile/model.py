"""L2 — LLaMA-architecture decoder in JAX, calling the L1 Pallas kernels.

Pure-functional: params are a dict (tests) or a canonically-ordered flat
list (AOT). Four entry points, one per artifact kind (see configs.AOT_PLAN):

    forward_prefill   contiguous-cache prefill  (flex causal kernel)
    forward_decode    contiguous-cache decode   ("default kernel" baseline)
    forward_paged     paged prefill/extend/decode over the KV pool
    forward_nocache   cache-less full recompute (Fig 3 baseline)
    forward_logits    full-sequence logits      (perplexity)

The paged path implements Alg. 1 end to end on device: GATHER is fused into
the Pallas kernels (block-table-indexed loads), ASSIGN is a functional
scatter into the pool (donated at AOT time, so it is in-place under PJRT),
and RESERVE stays in Rust (`kvpage`), which hands the model a block table
whose live range covers the new tokens.

Pool layout [L, P, page, Hkv, Dh] — one pool pair (K, V) for the whole
model, page-indexed per layer, matching the Rust `kvpage::pool` mirror.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import flex, mods
from .kernels.paged_prefill import paged_prefill_attention

Params = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) order — the AOT/manifest/Rust contract."""
    d, dh, ff, v = cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab_size
    spec = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.attn_norm", (d,)),
            (f"l{i}.wq", (d, cfg.n_heads * dh)),
            (f"l{i}.wk", (d, cfg.n_kv_heads * dh)),
            (f"l{i}.wv", (d, cfg.n_kv_heads * dh)),
            (f"l{i}.wo", (cfg.n_heads * dh, d)),
            (f"l{i}.mlp_norm", (d,)),
            (f"l{i}.w_gate", (d, ff)),
            (f"l{i}.w_up", (d, ff)),
            (f"l{i}.w_down", (ff, d)),
        ]
    spec += [("final_norm", (d,)), ("lm_head", (d, v))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Deterministic seeded init (the repo's 'checkpoint', DESIGN.md §1)."""
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else 1
            std = 0.02 if name in ("embed", "lm_head") else fan_in ** -0.5
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * std)
    return params


def params_to_list(cfg: ModelConfig, params: Params) -> List[jnp.ndarray]:
    return [params[name] for name, _ in param_spec(cfg)]


def params_from_list(cfg: ModelConfig, flat) -> Params:
    return {name: arr for (name, _), arr in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(positions, d_head, theta):
    """cos/sin tables for rotary embedding. positions [..., S] -> [..., S, dh/2]."""
    freqs = theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, H, S, dh]; cos/sin broadcastable to [B, 1, S, dh/2]."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _qkv(cfg, params, i, h):
    """h [B, S, d] -> q [B,H,S,dh], k/v [B,Hkv,S,dh] (pre-RoPE)."""
    b, s, _ = h.shape
    dh = cfg.d_head
    hn = rmsnorm(h, params[f"l{i}.attn_norm"], cfg.norm_eps)
    q = (hn @ params[f"l{i}.wq"]).reshape(b, s, cfg.n_heads, dh)
    k = (hn @ params[f"l{i}.wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = (hn @ params[f"l{i}.wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _attn_out(cfg, params, i, h, attn):
    """attn [B, H, S, dh] -> residual-added h."""
    b, _, s, _ = attn.shape
    merged = attn.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return h + merged @ params[f"l{i}.wo"]


def _mlp(cfg, params, i, h):
    hn = rmsnorm(h, params[f"l{i}.mlp_norm"], cfg.norm_eps)
    gate = jax.nn.silu(hn @ params[f"l{i}.w_gate"])
    return h + (gate * (hn @ params[f"l{i}.w_up"])) @ params[f"l{i}.w_down"]


def _logits(cfg, params, h):
    hn = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return hn @ params["lm_head"]


def _gather_last(x, lens):
    """x [B, S, ...] -> x[b, lens[b]-1] per batch."""
    idx = jnp.maximum(lens - 1, 0)
    return jnp.take_along_axis(
        x, idx[:, None, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# contiguous-cache path (the paper's baseline allocator / default kernel)
# ---------------------------------------------------------------------------


def forward_prefill(cfg: ModelConfig, params: Params, tokens, seq_lens,
                    interpret=True):
    """Contiguous prefill. tokens [B, S] i32, seq_lens [B] i32.

    Returns (logits_last [B, V], k_cache, v_cache [L, B, Hkv, M, dh]) with
    the cache zero-padded to the artifact's fixed capacity M = max_seq_len.
    """
    b, s = tokens.shape
    m = cfg.max_seq_len
    h = params["embed"][tokens]
    positions = jnp.arange(s)
    cos, sin = rope_tables(positions, cfg.d_head, cfg.rope_theta)
    cos, sin = cos[None, None], sin[None, None]
    mask = mods.padded_causal(seq_lens)
    bm = flex.create_block_mask_coarse(
        mask, b, cfg.n_heads, s, s,
        flex.DEFAULT_BLOCK_Q, flex.DEFAULT_BLOCK_K)
    k_layers, v_layers = [], []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(cfg, params, i, h)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        attn = flex.flex_attention(q, k, v, mask, block_mask=bm,
                                   interpret=interpret)
        h = _attn_out(cfg, params, i, h, attn)
        h = _mlp(cfg, params, i, h)
        pad = ((0, 0), (0, 0), (0, m - s), (0, 0))
        k_layers.append(jnp.pad(k, pad))
        v_layers.append(jnp.pad(v, pad))
    logits = _logits(cfg, params, _gather_last(h, seq_lens))
    return logits, jnp.stack(k_layers), jnp.stack(v_layers)


def forward_decode(cfg: ModelConfig, params: Params, tokens, k_cache,
                   v_cache, seq_lens):
    """Contiguous decode step ("default attention kernel", Fig 4 baseline).

    tokens [B] i32; caches [L, B, Hkv, M, dh]; seq_lens [B] = tokens already
    cached. Runs DENSE attention over the full M-capacity buffer with a
    length mask (the monolithic pre-allocated buffer the paper's Sec. I
    criticizes) merged with the current token's self-attention.
    Returns (logits [B, V], k_new, v_new [L, B, Hkv, dh]) — the cache
    write-back at position seq_lens[b] is the Rust engine's job.
    """
    m = cfg.max_seq_len
    h = params["embed"][tokens][:, None]  # [B, 1, d]
    cos, sin = rope_tables(seq_lens[:, None], cfg.d_head, cfg.rope_theta)
    cos, sin = cos[:, None], sin[:, None]  # [B,1,1,dh/2]
    t = jnp.arange(m)
    live = t[None, None, None, :] < seq_lens[:, None, None, None]
    scale = cfg.d_head ** -0.5
    n_rep = cfg.n_heads // cfg.n_kv_heads

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(cfg, params, i, h)  # q [B,H,1,dh]; k/v [B,Hkv,1,dh]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        new_k.append(k[:, :, 0])
        new_v.append(v[:, :, 0])
        kf = jnp.repeat(k_cache[i], n_rep, axis=1)  # [B,H,M,dh]
        vf = jnp.repeat(v_cache[i], n_rep, axis=1)
        s_cache = jnp.einsum("bhqd,bhkd->bhqk", q, kf) * scale
        s_cache = jnp.where(live, s_cache, -1e30)
        # current token attends to itself too (merged softmax)
        s_self = jnp.einsum("bhqd,bhkd->bhqk",
                            q, jnp.repeat(k, n_rep, axis=1)) * scale
        s = jnp.concatenate([s_cache, s_self], axis=-1)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", p[..., :m], vf) + \
            p[..., m:] * jnp.repeat(v, n_rep, axis=1)
        h = _attn_out(cfg, params, i, h, attn)
        h = _mlp(cfg, params, i, h)
    logits = _logits(cfg, params, h[:, 0])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# paged path (the paper's system)
# ---------------------------------------------------------------------------


def forward_paged(cfg: ModelConfig, params: Params, tokens, k_pool, v_pool,
                  block_tables, cache_lens, chunk_lens, interpret=True):
    """Paged forward over a KV pool view: prefill, extension, and decode.

    tokens [B, C] i32 (C == 1 is the decode step); pools
    [L, P, page, Hkv, dh] (P may be the *active subpool* the runtime
    gathers per step — see DESIGN.md §5); block_tables [B, maxB] i32
    indexes into that pool; cache_lens [B] = tokens already in pages;
    chunk_lens [B] <= C = live new tokens.

    GATHER is fused in the Pallas kernel (block-table-indexed loads).
    ASSIGN is Rust's job: this returns the chunk's new KV
    (k_chunk/v_chunk [L, B, Hkv, C, dh]) and the page manager scatters it
    into the authoritative pool (kvpage::pool::HostPool) — the runtime's
    xla_extension (0.5.1) returns tuple outputs as one host-roundtripped
    buffer, so device-resident pool feedback is not available; keeping the
    pool authoritative in Rust makes the shuttle one-directional.

    Returns (logits at each sequence's last live token [B, V],
    k_chunk, v_chunk).
    """
    b, c = tokens.shape
    h = params["embed"][tokens]
    positions = cache_lens[:, None] + jnp.arange(c)[None, :]  # [B, C]
    cos, sin = rope_tables(positions, cfg.d_head, cfg.rope_theta)
    cos, sin = cos[:, None], sin[:, None]
    block_q = 1 if c == 1 else min(32, c)

    k_layers, v_layers = [], []
    for i in range(cfg.n_layers):
        q, k, v = _qkv(cfg, params, i, h)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        # attend over (cached pages ++ the chunk itself, causal)
        attn = paged_prefill_attention(
            q, k, v, k_pool[i], v_pool[i], block_tables, cache_lens,
            block_q=block_q, interpret=interpret)
        k_layers.append(k)
        v_layers.append(v)
        h = _attn_out(cfg, params, i, h, attn)
        h = _mlp(cfg, params, i, h)
    logits = _logits(cfg, params, _gather_last(h, chunk_lens))
    return logits, jnp.stack(k_layers), jnp.stack(v_layers)


# ---------------------------------------------------------------------------
# no-cache + full-logits paths
# ---------------------------------------------------------------------------


def forward_nocache(cfg: ModelConfig, params: Params, tokens, seq_lens,
                    interpret=True):
    """Full recompute, no KV reuse (the Fig 3 'without caching' curve).

    Every generated token re-runs this over the whole prefix. Returns only
    the last live position's logits [B, V].
    """
    h = _backbone(cfg, params, tokens, seq_lens, interpret)
    return _logits(cfg, params, _gather_last(h, seq_lens))


def forward_logits(cfg: ModelConfig, params: Params, tokens, seq_lens,
                   interpret=True):
    """Full-sequence logits [B, S, V] (perplexity evaluation)."""
    h = _backbone(cfg, params, tokens, seq_lens, interpret)
    return _logits(cfg, params, h)


def _backbone(cfg, params, tokens, seq_lens, interpret):
    b, s = tokens.shape
    h = params["embed"][tokens]
    cos, sin = rope_tables(jnp.arange(s), cfg.d_head, cfg.rope_theta)
    cos, sin = cos[None, None], sin[None, None]
    mask = mods.padded_causal(seq_lens)
    bm = flex.create_block_mask_coarse(
        mask, b, cfg.n_heads, s, s,
        flex.DEFAULT_BLOCK_Q, flex.DEFAULT_BLOCK_K)
    for i in range(cfg.n_layers):
        q, k, v = _qkv(cfg, params, i, h)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        attn = flex.flex_attention(q, k, v, mask, block_mask=bm,
                                   interpret=interpret)
        h = _attn_out(cfg, params, i, h, attn)
        h = _mlp(cfg, params, i, h)
    return h

# ---------------------------------------------------------------------------
# pool-service executables (no model params)
# ---------------------------------------------------------------------------


def copy_pages(cfg: ModelConfig, k_pool, v_pool, src, dst):
    """Device-side page copy: pool[:, dst[i]] = pool[:, src[i]].

    Drives copy-on-write forks (kvpage::prefix): a child sequence diverging
    inside a shared partial page gets a private copy without the pool ever
    leaving the device. Entries with src/dst == n_pages are dropped
    (padding), so one fixed-[N] artifact serves any fork size.
    """
    p = cfg.n_pages
    valid = (src < p) & (dst < p)
    src_c = jnp.clip(src, 0, p - 1)
    dst_d = jnp.where(valid, dst, p)  # out of range -> scatter drop
    k2 = k_pool.at[:, dst_d].set(k_pool[:, src_c], mode="drop")
    v2 = v_pool.at[:, dst_d].set(v_pool[:, src_c], mode="drop")
    return k2, v2


def read_pages(cfg: ModelConfig, k_pool, v_pool, idx):
    """Gather pages to host (preemption swap-out / test inspection).

    idx [N] i32, clipped; caller masks invalid slots itself.
    Returns (k_pages [L,N,page,Hkv,dh], v_pages)."""
    idx_c = jnp.clip(idx, 0, cfg.n_pages - 1)
    return k_pool[:, idx_c], v_pool[:, idx_c]


def write_pages(cfg: ModelConfig, k_pool, v_pool, idx, k_vals, v_vals):
    """Scatter pages from host (preemption swap-in). idx == n_pages drops."""
    p = cfg.n_pages
    idx_d = jnp.where(idx < p, idx, p)
    k2 = k_pool.at[:, idx_d].set(k_vals, mode="drop")
    v2 = v_pool.at[:, idx_d].set(v_vals, mode="drop")
    return k2, v2
