"""Pallas chunked-prefill attention over paged KV (context extension).

Serves the paper's *Chat Growth* scenario (Sec. IV-A): a request arrives
with `cache_len` tokens already resident in KV pages and extends its context
by a chunk of C new tokens. Chunk queries must attend over

    [ cached pages (via block table) ] ++ [ the chunk itself, causally ]

in one fused kernel. The cached part is a page loop identical to
`paged_attention`; the chunk part is a tile loop with the causal mask_mod
applied at `q_offset = cache_len` — i.e. FlexAttention semantics with the
paper's page-translation indexing, composed.

Shapes: q/k/v chunk [B, H|Hkv, C, D]; pool/tables as in paged_attention;
cache_lens [B] int32 (tokens already in pages, a multiple of 1 — pages may
be partially filled). Output [B, H, C, D].
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 32


def _ceil_to(x, m):
    return ((x + m - 1) // m) * m


def paged_prefill_attention(q_chunk, k_chunk, v_chunk, k_pages, v_pages,
                            block_tables, cache_lens, *, scale=None,
                            block_q=DEFAULT_BLOCK_Q, pages_per_block=1,
                            interpret=True):
    """pages_per_block groups G pages into one loop iteration (G dynamic
    page loads -> ONE [block_q, G*page] score block). Measured on the CPU
    interpreter G=4 REGRESSED 12.2->18.2 ms/step (concat overhead beats
    loop savings — EXPERIMENTS.md §Perf iteration 1), so the default is 1;
    the knob exists because on real TPU larger G means larger MXU tiles
    per DMA and is the first thing to re-tune (DESIGN.md §8)."""
    b, h, c, d = q_chunk.shape
    n_pages, page_size, hkv, d2 = k_pages.shape
    assert d == d2 and h % hkv == 0
    n_rep = h // hkv
    max_blocks = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    orig_dtype = q_chunk.dtype

    c_p = _ceil_to(c, block_q)
    fp32 = jnp.float32
    q_chunk = q_chunk.astype(fp32)
    k_chunk = k_chunk.astype(fp32)
    v_chunk = v_chunk.astype(fp32)
    if c_p != c:
        pad = ((0, 0), (0, 0), (0, c_p - c), (0, 0))
        q_chunk = jnp.pad(q_chunk, pad)
        k_chunk = jnp.pad(k_chunk, pad)
        v_chunk = jnp.pad(v_chunk, pad)
    nq = c_p // block_q

    kernel = functools.partial(
        _paged_prefill_kernel, scale=scale, page_size=page_size,
        n_rep=n_rep, d=d, block_q=block_q, c=c, c_p=c_p,
        g=max(1, pages_per_block), max_blocks=max_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, c_p, d),
                         lambda bi, hi, qi, n_rep=n_rep: (bi, hi // n_rep, 0, 0)),
            pl.BlockSpec((1, 1, c_p, d),
                         lambda bi, hi, qi, n_rep=n_rep: (bi, hi // n_rep, 0, 0)),
            pl.BlockSpec((n_pages, page_size, hkv, d),
                         lambda bi, hi, qi: (0, 0, 0, 0)),
            pl.BlockSpec((n_pages, page_size, hkv, d),
                         lambda bi, hi, qi: (0, 0, 0, 0)),
            pl.BlockSpec((1, max_blocks), lambda bi, hi, qi: (bi, 0)),
            pl.BlockSpec((1,), lambda bi, hi, qi: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, c_p, d), fp32),
        interpret=interpret,
    )(q_chunk, k_chunk, v_chunk, k_pages.astype(fp32),
      v_pages.astype(fp32), block_tables.astype(jnp.int32),
      cache_lens.astype(jnp.int32))
    return out[:, :, :c].astype(orig_dtype)


def _paged_prefill_kernel(q_ref, kc_ref, vc_ref, kp_ref, vp_ref, bt_ref,
                          cl_ref, o_ref, *, scale, page_size, n_rep, d,
                          block_q, c, c_p, g, max_blocks):
    hi = pl.program_id(1)
    qi = pl.program_id(2)
    kvh = hi // n_rep
    q_tile = q_ref[0, 0] * scale  # [block_q, D]
    cache_len = cl_ref[0]
    chunk_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    # --- phase 1: cached pages via the block table (GATHER), processed
    # in super-blocks of g pages per loop iteration -------------------------
    n_cached_blocks = (cache_len + page_size - 1) // page_size
    n_super = (n_cached_blocks + g - 1) // g
    sb = g * page_size  # tokens per super-block

    def page_body(j, carry):
        m, l, acc = carry
        k_parts, v_parts = [], []
        for gi in range(g):
            idx = j * g + gi if g > 1 else j
            if g > 1:
                idx = jnp.minimum(idx, max_blocks - 1)
            page = pl.load(bt_ref, (0, pl.ds(idx, 1)))[0]
            k_parts.append(pl.load(
                kp_ref, (pl.ds(page, 1), slice(None), pl.ds(kvh, 1),
                         slice(None))).reshape(page_size, d))
            v_parts.append(pl.load(
                vp_ref, (pl.ds(page, 1), slice(None), pl.ds(kvh, 1),
                         slice(None))).reshape(page_size, d))
        k_blk = (k_parts[0] if g == 1
                 else jnp.concatenate(k_parts, axis=0))  # [g*page, D]
        v_blk = (v_parts[0] if g == 1
                 else jnp.concatenate(v_parts, axis=0))
        t = j * sb + jax.lax.iota(jnp.int32, sb)
        live = (t < cache_len)[None, :]  # cached tokens precede queries
        s = jnp.dot(q_tile, k_blk.T)  # [block_q, g*page]
        s = jnp.where(live, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(live, jnp.exp(s - m_new[:, None]), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, d), jnp.float32))
    carry = jax.lax.fori_loop(0, n_super, page_body, init)

    # --- phase 2: the chunk itself, causal, only tiles j <= qi ------------
    def chunk_body(j, carry):
        m, l, acc = carry
        k_blk = pl.load(kc_ref, (0, 0, pl.ds(j * block_q, block_q),
                                 slice(None)))
        v_blk = pl.load(vc_ref, (0, 0, pl.ds(j * block_q, block_q),
                                 slice(None)))
        kv_idx = j * block_q + jax.lax.iota(jnp.int32, block_q)
        allowed = (kv_idx[None, :] <= chunk_idx[:, None]) & \
                  (kv_idx[None, :] < c)
        s = jnp.dot(q_tile, k_blk.T)
        s = jnp.where(allowed, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(allowed, jnp.exp(s - m_new[:, None]), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, qi + 1, chunk_body, carry)
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)[:, None]
