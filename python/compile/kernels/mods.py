"""FlexAttention-style mask_mod / score_mod library.

The paper leverages PyTorch FlexAttention's contract: attention variants are
expressed as two small index-level callables that the compiler fuses into a
single kernel,

    mask_mod(b, h, q_idx, kv_idx)           -> bool   (True = attend)
    score_mod(score, b, h, q_idx, kv_idx)   -> score

Here the same contract is traced into our Pallas kernel (`flex.py`). All
mods must be pure jnp functions of broadcastable integer arrays — they are
evaluated both element-wise inside kernel tiles and block-wise when the
BlockMask is built, so they must not assume scalar inputs.

Mods that depend on *data* (per-batch lengths, sequence ids, bias tables)
cannot capture those arrays as closure constants: Pallas requires every
array entering a kernel to be an explicit input. Such mods are `Mod`
instances carrying `aux` arrays; `flex.flex_attention` hoists the aux into
kernel inputs and re-binds them inside the kernel (the analog of
FlexAttention passing auxiliary vectors "as bias", Sec. III-B of the paper).

The paper's own kernel (Sec. III-B) is `sequence_local`: allow iff
(id_q == id_k) AND (kv < len(id_q)), built from a sequence-id vector and a
prefix-sum vector — both constructed here.
"""

from __future__ import annotations

import jax.numpy as jnp


class Mod:
    """A mask/score mod with explicit auxiliary arrays.

    `fn` receives the index args followed by the aux arrays. Calling the Mod
    directly (host-side: oracles, BlockMask builders) injects the stored
    aux; the Pallas kernel instead re-binds aux to values loaded from kernel
    input refs via `bind`.
    """

    __slots__ = ("fn", "aux")

    def __init__(self, fn, aux=()):
        self.fn = fn
        self.aux = tuple(jnp.asarray(a) for a in aux)

    def __call__(self, *idx_args):
        return self.fn(*idx_args, *self.aux)

    def bind(self, aux_vals):
        """Return a plain callable with aux replaced by `aux_vals`."""
        fn = self.fn
        aux_vals = tuple(aux_vals)
        return lambda *idx_args: fn(*idx_args, *aux_vals)


def as_mod(m):
    """Normalize a plain callable or Mod to a Mod."""
    if m is None or isinstance(m, Mod):
        return m
    return Mod(lambda *args, _f=m: _f(*args))


# ---------------------------------------------------------------------------
# mask mods
# ---------------------------------------------------------------------------


def causal(b, h, q_idx, kv_idx):
    """Standard autoregressive mask: each query sees itself and the past."""
    return kv_idx <= q_idx


def full(b, h, q_idx, kv_idx):
    """No masking (bidirectional attention)."""
    shape = jnp.broadcast_shapes(jnp.shape(q_idx), jnp.shape(kv_idx))
    return jnp.full(shape, True)


def sliding_window(window: int):
    """Causal sliding-window mask of `window` tokens (Mistral-style)."""

    def mod(b, h, q_idx, kv_idx):
        return (kv_idx <= q_idx) & (q_idx - kv_idx < window)

    return mod


def prefix_lm(prefix_len: int):
    """Bidirectional over the first `prefix_len` tokens, causal after."""

    def mod(b, h, q_idx, kv_idx):
        return (kv_idx < prefix_len) | (kv_idx <= q_idx)

    return mod


def padded_causal(seq_lens):
    """Causal, but keys beyond the per-batch live length are dead.

    seq_lens: [B] int array, aux-bound (indexed by the mod's `b` argument).
    """

    def fn(b, h, q_idx, kv_idx, seq_lens):
        return (kv_idx <= q_idx) & (kv_idx < seq_lens[b])

    return Mod(fn, aux=(seq_lens,))


def sequence_local(seq_ids, seq_lens):
    """The paper's jagged-batch mask (Sec. III-B).

    Multiple variable-length sequences are packed along one axis;
    `seq_ids[t]` gives the sequence owning slot t and `seq_lens[s]` the live
    length of sequence s. allow <=> (id_q == id_k) & causal-within-sequence
    & kv within the live length — exactly the paper's
    (id_q = id_k) AND (k <= len(id_q)) with causality made explicit. The
    prefix-sum start-offset vector is the paper's second auxiliary vector.
    """
    seq_ids = jnp.asarray(seq_ids)
    starts = prefix_starts(seq_ids)

    def fn(b, h, q_idx, kv_idx, seq_ids, seq_lens, starts):
        same = seq_ids[q_idx] == seq_ids[kv_idx]
        kv_local = kv_idx - starts[seq_ids[kv_idx]]
        live = kv_local < seq_lens[seq_ids[q_idx]]
        return same & (kv_idx <= q_idx) & live

    return Mod(fn, aux=(seq_ids, seq_lens, starts))


def prefix_starts(seq_ids):
    """Prefix-sum auxiliary vector: start offset of each sequence id.

    For seq_ids like [0,0,0,1,1,2,...] returns [0,3,5,...]. This is the
    second auxiliary vector of Sec. III-B.
    """
    seq_ids = jnp.asarray(seq_ids)
    n = int(seq_ids.max()) + 1 if seq_ids.size else 0
    counts = jnp.bincount(seq_ids, length=n)
    return jnp.concatenate(
        [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])


def document(doc_ids):
    """Document mask: attend only within the same document, causal."""

    def fn(b, h, q_idx, kv_idx, doc_ids):
        return (doc_ids[q_idx] == doc_ids[kv_idx]) & (kv_idx <= q_idx)

    return Mod(fn, aux=(doc_ids,))


def and_masks(*mask_mods):
    """Conjunction of mask mods (FlexAttention's and_masks)."""
    norm = [as_mod(m) for m in mask_mods]
    splits = _aux_splits(norm)

    def fn(b, h, q_idx, kv_idx, *aux):
        out = None
        for m, (lo, hi) in zip(norm, splits):
            r = m.fn(b, h, q_idx, kv_idx, *aux[lo:hi])
            out = r if out is None else (out & r)
        return out

    return Mod(fn, aux=[a for m in norm for a in m.aux])


def or_masks(*mask_mods):
    """Disjunction of mask mods (FlexAttention's or_masks)."""
    norm = [as_mod(m) for m in mask_mods]
    splits = _aux_splits(norm)

    def fn(b, h, q_idx, kv_idx, *aux):
        out = None
        for m, (lo, hi) in zip(norm, splits):
            r = m.fn(b, h, q_idx, kv_idx, *aux[lo:hi])
            out = r if out is None else (out | r)
        return out

    return Mod(fn, aux=[a for m in norm for a in m.aux])


def _aux_splits(norm_mods):
    splits, off = [], 0
    for m in norm_mods:
        splits.append((off, off + len(m.aux)))
        off += len(m.aux)
    return splits


# ---------------------------------------------------------------------------
# score mods
# ---------------------------------------------------------------------------


def identity_score(score, b, h, q_idx, kv_idx):
    return score


def alibi(n_heads: int):
    """ALiBi linear positional bias: score -= slope(h) * (q - kv)."""

    def mod(score, b, h, q_idx, kv_idx):
        # slope = 2^-(8*(h+1)/H), the standard ALiBi schedule.
        slope = jnp.exp2(-8.0 * (jnp.asarray(h, jnp.float32) + 1.0) / n_heads)
        return score - slope * (q_idx - kv_idx).astype(jnp.float32)

    return mod


def soft_cap(cap: float):
    """Gemma2-style logit soft-capping: cap * tanh(score / cap)."""

    def mod(score, b, h, q_idx, kv_idx):
        return cap * jnp.tanh(score / cap)

    return mod


def relative_bias(bias_table):
    """Learned relative-position bias lookup, clamped to the table size."""
    span = jnp.asarray(bias_table).shape[-1]

    def fn(score, b, h, q_idx, kv_idx, bias_table):
        rel = jnp.clip(q_idx - kv_idx, 0, span - 1)
        return score + bias_table[h, rel]

    return Mod(fn, aux=(bias_table,))


def compose_scores(*score_mods):
    """Apply score mods left-to-right."""
    norm = [as_mod(m) for m in score_mods]
    splits = _aux_splits(norm)

    def fn(score, b, h, q_idx, kv_idx, *aux):
        for m, (lo, hi) in zip(norm, splits):
            score = m.fn(score, b, h, q_idx, kv_idx, *aux[lo:hi])
        return score

    return Mod(fn, aux=[a for m in norm for a in m.aux])
