"""L1 — Pallas kernels for paged + flex attention (build-time only).

Public surface:
    flex.flex_attention            fused FlexAttention engine (mask/score mods)
    flex.create_block_mask         sound BlockMask builder
    flex.create_block_mask_coarse  corner-sampled BlockMask (monotone mods)
    mods.*                         mask_mod / score_mod library
    paged_attention.paged_decode_attention   decode over KV pages (Alg. 1 GATHER)
    paged_prefill.paged_prefill_attention    chunked prefill over pages + chunk
    ref.*                          dense jnp oracles for all of the above
"""

from . import flex, mods, paged_attention, paged_prefill, ref  # noqa: F401
