"""Pallas FlexAttention engine.

This is the repo's analog of PyTorch FlexAttention (Sec. II-A.2 / III-B of
the paper): ONE tiled, online-softmax attention kernel whose behaviour is
specialized at trace time by user-supplied `mask_mod` / `score_mod`
callables (see `mods.py`). The mods are traced directly into the kernel body
— the Pallas equivalent of TorchInductor fusing `mask_mod` into the
QK^T·V loop — so every variant (causal, jagged sequence-local, sliding
window, ALiBi, ...) compiles to a single fused kernel, not a mask tensor in
HBM.

Block-level sparsity (FlexAttention's BlockMask) is reproduced: a
[B, H, nQ, nK] uint8 block-liveness map is computed once per mask and each
fully-dead KV tile is skipped inside the kernel with `lax.cond`.

Hardware adaptation (DESIGN.md §2): CUDA threadblock tiles become the Pallas
grid (B, H, nQ); per-tile staging into shared memory becomes BlockSpec
HBM->VMEM copies; warp softmax becomes the (m, l, acc) running reduction.
`interpret=True` is mandatory on this image — real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .mods import as_mod
from .ref import NEG_INF

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 64


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def create_block_mask(mask_mod, b, h, sq, skv, block_q=DEFAULT_BLOCK_Q,
                      block_k=DEFAULT_BLOCK_K, q_offset=0):
    """Dense (sound for ANY mod) BlockMask: uint8 [B, H, nQ, nK].

    A block is live iff any element inside it is allowed. Evaluates the mod
    on the full index grid one (b, h) at a time to bound memory.
    """
    nq = _ceil_to(sq, block_q) // block_q
    nk = _ceil_to(skv, block_k) // block_k
    qi = (jnp.arange(nq * block_q) + q_offset)[:, None]
    ki = jnp.arange(nk * block_k)[None, :]
    valid = (qi - q_offset < sq) & (ki < skv)
    rows = []
    for bi in range(b):
        heads = []
        for hi in range(h):
            dense = mask_mod(bi, hi, qi, ki) & valid
            blk = dense.reshape(nq, block_q, nk, block_k).any(axis=(1, 3))
            heads.append(blk)
        rows.append(jnp.stack(heads))
    return jnp.stack(rows).astype(jnp.uint8)


def create_block_mask_coarse(mask_mod, b, h, sq, skv,
                             block_q=DEFAULT_BLOCK_Q,
                             block_k=DEFAULT_BLOCK_K, q_offset=0):
    """Corner-sampled BlockMask: sound for block-monotone mods only.

    Evaluates the mod at the four corners of every (q-block, kv-block) tile
    and marks the block live if any corner allows. Correct for mods whose
    allowed region is axis-monotone within a block (causal, sliding window,
    padded_causal, prefix_lm, document with sorted ids) — i.e. every mod this
    repo AOT-compiles. O(nQ*nK) instead of O(Sq*Skv); usable under jit with
    traced mod closures (e.g. padded_causal(seq_lens) at prefill).
    """
    nq = _ceil_to(sq, block_q) // block_q
    nk = _ceil_to(skv, block_k) // block_k
    q_lo = jnp.arange(nq) * block_q + q_offset
    q_hi = jnp.minimum(q_lo + block_q - 1, q_offset + sq - 1)
    k_lo = jnp.arange(nk) * block_k
    k_hi = jnp.minimum(k_lo + block_k - 1, skv - 1)
    bi = jnp.arange(b)[:, None, None, None]
    hi = jnp.arange(h)[None, :, None, None]
    live = None
    for qc in (q_lo, q_hi):
        for kc in (k_lo, k_hi):
            m = mask_mod(bi, hi, qc[None, None, :, None],
                         kc[None, None, None, :])
            m = jnp.broadcast_to(m, (b, h, nq, nk))
            live = m if live is None else (live | m)
    return live.astype(jnp.uint8)


def flex_attention(q, k, v, mask_mod=None, score_mod=None, *, scale=None,
                   block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                   q_offset=0, block_mask=None, return_lse=False,
                   interpret=True):
    """Fused attention with FlexAttention semantics.

    q [B,H,Sq,D], k/v [B,Hkv,Skv,D] (GQA when Hkv<H). `q_offset` shifts the
    logical position of q rows — decode/chunked-prefill pass the number of
    already-cached tokens. `block_mask` may be precomputed with
    create_block_mask[_coarse]; if omitted and mask_mod is given, the dense
    (always-sound) builder runs.

    Returns out [B,H,Sq,D] (and lse [B,H,Sq] if return_lse).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert h % hkv == 0, f"GQA needs H({h}) % Hkv({hkv}) == 0"
    n_rep = h // hkv
    mask_mod = as_mod(mask_mod)
    score_mod = as_mod(score_mod)
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    orig_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    sq_p = _ceil_to(sq, block_q)
    skv_p = _ceil_to(skv, block_k)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nq, nk = sq_p // block_q, skv_p // block_k

    if block_mask is None and mask_mod is not None:
        block_mask = create_block_mask(mask_mod, b, h, sq, skv, block_q,
                                       block_k, q_offset)
    if block_mask is None:
        block_mask = jnp.ones((b, h, nq, nk), jnp.uint8)
    assert block_mask.shape == (b, h, nq, nk), (
        f"block_mask {block_mask.shape} != {(b, h, nq, nk)}")

    # Mod aux arrays (per-batch lengths, sequence ids, bias tables, ...)
    # enter the kernel as explicit full-array inputs (Sec. III-B's
    # "auxiliary vectors passed as bias").
    mask_aux = mask_mod.aux if mask_mod is not None else ()
    score_aux = score_mod.aux if score_mod is not None else ()
    aux = [jnp.asarray(a) for a in (*mask_aux, *score_aux)]
    aux_specs = [
        pl.BlockSpec(a.shape, functools.partial(
            lambda *_, nd: (0,) * nd, nd=a.ndim))
        for a in aux
    ]

    kernel = functools.partial(
        _flex_kernel, scale=scale, mask_mod=mask_mod, score_mod=score_mod,
        n_mask_aux=len(mask_aux), n_score_aux=len(score_aux),
        block_q=block_q, block_k=block_k, n_kv_blocks=nk, skv=skv,
        q_offset=q_offset, d=d)

    out, lse = pl.pallas_call(
        kernel,
        grid=(b, h, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, skv_p, d),
                         lambda bi, hi, qi, n_rep=n_rep: (bi, hi // n_rep, 0, 0)),
            pl.BlockSpec((1, 1, skv_p, d),
                         lambda bi, hi, qi, n_rep=n_rep: (bi, hi // n_rep, 0, 0)),
            pl.BlockSpec((1, 1, 1, nk), lambda bi, hi, qi: (bi, hi, qi, 0)),
            *aux_specs,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq_p, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq_p), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, block_mask, *aux)

    out = out[:, :, :sq].astype(orig_dtype)
    if return_lse:
        return out, lse[:, :, :sq]
    return out


def _flex_kernel(q_ref, k_ref, v_ref, bm_ref, *refs, scale, mask_mod,
                 score_mod, n_mask_aux, n_score_aux, block_q, block_k,
                 n_kv_blocks, skv, q_offset, d):
    """One (batch, head, q-tile) grid step: online softmax over KV tiles."""
    aux_refs, (o_ref, lse_ref) = refs[:-2], refs[-2:]
    aux_vals = [r[...] for r in aux_refs]
    mask_fn = mask_mod.bind(aux_vals[:n_mask_aux]) if mask_mod else None
    score_fn = (score_mod.bind(aux_vals[n_mask_aux:])
                if score_mod else None)
    bi = pl.program_id(0)
    hi = pl.program_id(1)
    qi = pl.program_id(2)
    q_tile = q_ref[0, 0]  # [block_q, D], already VMEM-resident
    q_ids = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def process_block(j, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (0, 0, pl.ds(j * block_k, block_k),
                                slice(None)))  # [block_k, D]
        v_blk = pl.load(v_ref, (0, 0, pl.ds(j * block_k, block_k),
                                slice(None)))
        kv_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.dot(q_tile, k_blk.T) * scale  # [block_q, block_k]
        if score_fn is not None:
            s = score_fn(s, bi, hi, q_ids[:, None], kv_ids[None, :])
        allowed = kv_ids[None, :] < skv  # kill right-padding keys
        if mask_fn is not None:
            allowed = allowed & mask_fn(bi, hi, q_ids[:, None],
                                        kv_ids[None, :])
        s = jnp.where(allowed, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(allowed, p, 0.0)  # NEG_INF rows: keep exact zeros
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    def body(j, carry):
        live = pl.load(bm_ref, (0, 0, 0, pl.ds(j, 1)))[0] > 0
        return jax.lax.cond(live, lambda c: process_block(j, c),
                            lambda c: c, carry)

    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, d), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, init)
    safe_l = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = acc / safe_l[:, None]
    lse_ref[0, 0] = m + jnp.log(safe_l)
