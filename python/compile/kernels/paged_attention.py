"""Pallas paged decode attention — Alg. 1 GATHER fused into the kernel.

Decode-time attention of one new query token per sequence over that
sequence's KV pages, addressed through its block table. This is the kernel
the paper builds with FlexAttention's `mask_mod` (Sec. III-B): instead of a
dense gather into contiguous buffers (ref.gather_pages), the page
indirection happens *inside* the fused kernel — each KV tile load is a
block-table-indexed dynamic slice on the pool's leading (page) axis, the TPU
analog of vLLM's coalesced page reads.

Pool layout (shared with the Rust `kvpage` pool and the L2 model):
    k_pages, v_pages : [P, page_size, Hkv, D]
    block_tables     : [B, max_blocks] int32 (entries beyond the live range
                       may be arbitrary: they are masked by seq_lens)
    seq_lens         : [B] int32, live tokens per sequence (incl. current)

Grid is (B, H): one step per (sequence, query head). The page loop is a
`fori_loop` bounded by the *live* block count, so dead table tail entries
are never touched — matching the O(len) work bound of Alg. 1 GATHER.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           scale=None, interpret=True):
    """q [B,H,D] against paged KV; returns [B,H,D].

    seq_lens counts the tokens each query may attend to (the current token's
    K/V must already be ASSIGNed into the pool by the page manager).
    """
    b, h, d = q.shape
    n_pages, page_size, hkv, d2 = k_pages.shape
    assert d == d2 and h % hkv == 0
    n_rep = h // hkv
    max_blocks = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    orig_dtype = q.dtype

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, page_size=page_size,
        n_rep=n_rep, d=d)

    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
            # Whole pool visible to every grid step; page selection is a
            # runtime dynamic slice driven by the block table (GATHER).
            pl.BlockSpec((n_pages, page_size, hkv, d),
                         lambda bi, hi: (0, 0, 0, 0)),
            pl.BlockSpec((n_pages, page_size, hkv, d),
                         lambda bi, hi: (0, 0, 0, 0)),
            pl.BlockSpec((1, max_blocks), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), k_pages.astype(jnp.float32),
      v_pages.astype(jnp.float32), block_tables.astype(jnp.int32),
      seq_lens.astype(jnp.int32))
    return out.astype(orig_dtype)


def _paged_decode_kernel(q_ref, kp_ref, vp_ref, bt_ref, sl_ref, o_ref, *,
                         scale, page_size, n_rep, d):
    hi = pl.program_id(1)
    kvh = hi // n_rep
    q = q_ref[0, 0] * scale  # [D]
    seq_len = sl_ref[0]
    n_blocks = (seq_len + page_size - 1) // page_size

    def body(j, carry):
        m, l, acc = carry
        page = pl.load(bt_ref, (0, pl.ds(j, 1)))[0]
        # [1, page, 1, D] -> [page, D]; one contiguous DMA per page.
        k_blk = pl.load(kp_ref, (pl.ds(page, 1), slice(None),
                                 pl.ds(kvh, 1), slice(None)))
        k_blk = k_blk.reshape(page_size, d)
        v_blk = pl.load(vp_ref, (pl.ds(page, 1), slice(None),
                                 pl.ds(kvh, 1), slice(None)))
        v_blk = v_blk.reshape(page_size, d)
        s = jnp.dot(k_blk, q)  # [page]
        t = j * page_size + jax.lax.iota(jnp.int32, page_size)
        live = t < seq_len
        s = jnp.where(live, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        alpha = jnp.exp(m - m_new)
        p = jnp.where(live, jnp.exp(s - m_new), 0.0)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    init = (jnp.float32(NEG_INF), jnp.float32(0.0),
            jnp.zeros((d,), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)
