"""Pure-jnp reference oracles for every kernel in this package.

These are the correctness signal: each Pallas kernel in `flex.py`,
`paged_attention.py` and `paged_prefill.py` is pytest-checked against the
corresponding function here with `assert_allclose`. Everything is written in
the most obvious O(S^2) dense form — no tiling, no online softmax — so a bug
in the optimized kernels cannot be mirrored here.

Shape conventions (shared across the package):
    q            [B, H,  Sq, D]    queries
    k, v         [B, Hkv, Skv, D]  keys/values (GQA when Hkv < H)
    k/v pages    [P, page, Hkv, D] the global paged KV pool (Alg. 1's K, V)
    block_tables [B, max_blocks]   logical block -> physical page (page_table)
    seq_lens     [B]               tokens currently live per sequence
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf: keeps softmax NaN-free when a
# whole row is masked (exp(NEG_INF - NEG_INF) paths stay finite).


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """Expand KV heads for grouped-query attention: [B,Hkv,S,D] -> [B,Hkv*n,S,D]."""
    if n_rep == 1:
        return x
    b, h, s, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d
    )


def ref_attention(q, k, v, mask=None, scale=None):
    """Dense softmax(q k^T) v with an optional boolean mask.

    mask broadcasts to [B, H, Sq, Skv]; True = attend.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    n_rep = q.shape[1] // k.shape[1]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def materialize_mask(mask_mod, b, h, sq, skv, q_offset=0):
    """Evaluate a FlexAttention-style mask_mod on the full index grid.

    mask_mod(b, h, q_idx, kv_idx) -> bool, with jnp broadcasting; this builds
    the dense [B, H, Sq, Skv] boolean tensor the mod describes.
    """
    bi = jnp.arange(b)[:, None, None, None]
    hi = jnp.arange(h)[None, :, None, None]
    qi = (jnp.arange(sq) + q_offset)[None, None, :, None]
    ki = jnp.arange(skv)[None, None, None, :]
    return jnp.broadcast_to(mask_mod(bi, hi, qi, ki), (b, h, sq, skv))


def ref_flex_attention(q, k, v, mask_mod=None, score_mod=None, scale=None,
                       q_offset=0):
    """Oracle for flex.flex_attention: dense eval of mask_mod/score_mod."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    b, h, sq, d = q.shape
    skv = k.shape[2]
    n_rep = h // k.shape[1]
    kf = repeat_kv(k, n_rep)
    vf = repeat_kv(v, n_rep)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kf) * scale
    if score_mod is not None:
        bi = jnp.arange(b)[:, None, None, None]
        hi = jnp.arange(h)[None, :, None, None]
        qi = (jnp.arange(sq) + q_offset)[None, None, :, None]
        ki = jnp.arange(skv)[None, None, None, :]
        scores = score_mod(scores, bi, hi, qi, ki)
    if mask_mod is not None:
        mask = materialize_mask(mask_mod, b, h, sq, skv, q_offset)
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vf)


def gather_pages(pages, block_table, length, page_size):
    """Alg. 1 GATHER for one sequence, dense: [len, Hkv, D] from the pool."""
    n_blocks = (length + page_size - 1) // page_size
    out = []
    for j in range(n_blocks):
        page = pages[int(block_table[j])]  # [page, Hkv, D]
        take = min(page_size, length - j * page_size)
        out.append(page[:take])
    return jnp.concatenate(out, axis=0)


def ref_paged_decode(q, k_pages, v_pages, block_tables, seq_lens, page_size,
                     scale=None):
    """Oracle for paged_attention.paged_decode_attention.

    q: [B, H, D] (one new token per sequence). Gathers each sequence's pages
    densely, then runs full attention of the single query over them.
    """
    b, h, d = q.shape
    outs = []
    for i in range(b):
        length = int(seq_lens[i])
        ks = gather_pages(k_pages, block_tables[i], length, page_size)
        vs = gather_pages(v_pages, block_tables[i], length, page_size)
        # [1, Hkv, len, D]
        ks = ks.transpose(1, 0, 2)[None]
        vs = vs.transpose(1, 0, 2)[None]
        qi = q[i][None, :, None, :]  # [1, H, 1, D]
        outs.append(ref_attention(qi, ks, vs, scale=scale)[0, :, 0])
    return jnp.stack(outs)  # [B, H, D]


def ref_paged_prefill(q_chunk, k_chunk, v_chunk, k_pages, v_pages,
                      block_tables, cache_lens, page_size, scale=None):
    """Oracle for paged_prefill.paged_prefill_attention.

    Chunk queries attend over (cached pages ++ chunk) with causal masking
    inside the chunk: query t of the chunk sees cache_len + t + 1 keys.
    q_chunk: [B, H, C, D], k_chunk/v_chunk: [B, Hkv, C, D].
    """
    b, h, c, d = q_chunk.shape
    outs = []
    for i in range(b):
        cl = int(cache_lens[i])
        if cl > 0:
            ks_cache = gather_pages(k_pages, block_tables[i], cl, page_size)
            vs_cache = gather_pages(v_pages, block_tables[i], cl, page_size)
            ks = jnp.concatenate([ks_cache, k_chunk[i].transpose(1, 0, 2)], 0)
            vs = jnp.concatenate([vs_cache, v_chunk[i].transpose(1, 0, 2)], 0)
        else:
            ks = k_chunk[i].transpose(1, 0, 2)
            vs = v_chunk[i].transpose(1, 0, 2)
        total = cl + c
        qi = jnp.arange(c)[:, None] + cl
        ki = jnp.arange(total)[None, :]
        mask = ki <= qi  # causal: chunk token t sees cache + itself
        outs.append(
            ref_attention(
                q_chunk[i][None],
                ks.transpose(1, 0, 2)[None],
                vs.transpose(1, 0, 2)[None],
                mask=mask[None, None],
                scale=scale,
            )[0]
        )
    return jnp.stack(outs)  # [B, H, C, D]
